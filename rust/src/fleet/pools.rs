//! Disaggregated prefill/decode pools with failure-aware KV handoff.
//!
//! When `[fleet.pools]` is armed, the fleet's replicas split into two
//! contiguous pools — replicas `[0, prefill)` run prompt prefill,
//! replicas `[prefill, prefill + decode)` run token decode — and each
//! logical request becomes a three-leg lifecycle:
//!
//! 1. **Prefill leg.** The router dispatches the arrival into the
//!    prefill pool with `max_new_tokens` clamped to 1: the prefill
//!    replica tokenizes, prefills, and emits the first token.
//! 2. **KV handoff.** The prompt's KV pages travel to the decode pool
//!    as an explicit *copy task* on the source replica's tokenizer
//!    executor — the same simulated CPU pool tokenization contends for,
//!    so a handoff-heavy fleet starves its own encodes exactly the way
//!    the paper's CPU-contention story predicts. Cost =
//!    `transfer_base_s + prompt_tokens × kv_bytes_per_token /
//!    transfer_gb_per_s`. The handoff is a first-class failure domain:
//!    [`FaultSpec::TransferStall`] stretches an attempt,
//!    [`FaultSpec::TransferLoss`] kills it, both by the same pure-hash
//!    fires-or-not rule as every other fault stream. Lost attempts
//!    retry with the engine's deterministic per-origin backoff up to
//!    `transfer_max_attempts`; an exhausted budget falls back to
//!    **re-prefill in the decode pool** (counted as a retry on the
//!    fleet ledger — the prefill work is genuinely redone).
//! 3. **Decode leg.** A completed handoff delivers a `kv_received`
//!    request to a decode replica: the scheduler recomputes only the
//!    last prompt token and streams decode from there. Decode delivery
//!    is the request's normal second leg, *not* a retry.
//!
//! **Backpressure.** While the decode pool is saturated (live decode
//! deliveries + in-flight transfers ≥ `max_inflight_per_decode ×`
//! decode replicas), new disagg dispatches defer by one router tick —
//! prefill throttles instead of piling KV onto a full decode pool.
//!
//! **Colocated fallback.** Pool health generalizes the per-replica
//! hysteresis machine: a pool is Down when *every* member replica's
//! [`HealthState`] is Down (each member individually filtered through
//! `down_after`/`recover_after` streaks). While either pool is Down the
//! fleet serves new origins colocated — any replica runs both phases —
//! and flips back the probe window the pool recovers.
//!
//! **Determinism.** Every decision here is pure in `(fleet seed,
//! origin, probe window, attempt)`: pool membership is a fixed index
//! split, transfer faults are pure hashes, retry backoff reuses the
//! engine's per-origin jitter stream, and deferred dispatches fire at
//! fixed tick multiples. Disagg runs are byte-identical across
//! `--jobs` and replayable from dumped traces. With pools disabled
//! every hook below is dead code on the dispatch path, so colocated
//! fleets stay byte-identical to builds without this module.

use super::{health::HealthState, router, Arm, FleetShared};
use crate::config::PoolConfig;
use crate::engine::{self, TokJob};
use crate::profile::SpanKind;
use crate::simcpu::Sim;
use rustc_hash::FxHashMap;

/// Lifecycle stage of a logical request under disaggregation. Origins
/// in a pools-disabled fleet stay `Colocated` forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Stage {
    /// Single delivery runs prefill + decode on one replica (pools off,
    /// colocated fallback, or a ≤1-token request with nothing to hand
    /// off).
    Colocated,
    /// Prefill leg live in the prefill pool (`max_new` clamped to 1).
    Prefill,
    /// KV handoff in flight: no live delivery; [`PoolCtl::transfers`]
    /// owns the origin until the copy lands or exhausts its budget.
    Transfer,
    /// Decode leg live in the decode pool (prefilled delivery, or a
    /// full re-prefill after transfer/decode failure).
    Decode,
}

/// One in-flight KV handoff (keyed by fleet origin).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Transfer {
    /// Source prefill replica — scopes transfer faults and carries the
    /// copy task on its tokenizer executor.
    pub(crate) src: usize,
    /// Attempts launched so far (1-based once the first starts).
    pub(crate) attempt: u32,
    /// When the handoff began (prefill completion) — the Handoff span
    /// and `ph_handoff_ns` measure from here, retries included.
    pub(crate) started_ns: u64,
    /// When the current attempt launched — anchors its fault windows.
    pub(crate) launched_ns: u64,
}

/// Aggregate disaggregation counters for reports and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolSummary {
    pub prefill_replicas: usize,
    pub decode_replicas: usize,
    /// Logical requests that entered a KV handoff.
    pub handoffs_started: u64,
    /// Handoffs that delivered their KV to a decode replica.
    pub handoffs_completed: u64,
    /// Transfer attempts relaunched after a TransferLoss strike.
    pub transfer_retries: u64,
    /// Handoffs that exhausted `transfer_max_attempts`.
    pub transfer_failures: u64,
    /// Full re-prefill dispatches into the decode pool (failed transfer
    /// or no eligible decode replica at handoff completion).
    pub reprefills: u64,
    /// New-origin dispatches deferred by decode-pool saturation.
    pub backpressure_deferrals: u64,
    /// New origins served colocated while a pool was Down.
    pub colocated_fallbacks: u64,
    /// Probe windows the fleet spent in colocated-fallback mode.
    pub colocated_windows: u64,
}

/// Disaggregation state inside [`super::FleetCtl`]. `Default` keeps
/// every `FleetCtl` construction site (tests included) a one-liner and
/// is the entire cost of the feature when pools are off.
#[derive(Debug, Default)]
pub(crate) struct PoolCtl {
    /// Either pool is Down → new origins dispatch colocated.
    pub(crate) colocated_mode: bool,
    pub(crate) transfers: FxHashMap<u64, Transfer>,
    pub(crate) stats: PoolSummary,
}

/// Replica index range `[lo, hi)` of the prefill pool.
pub(crate) fn prefill_range(pl: &PoolConfig) -> (usize, usize) {
    (0, pl.prefill)
}

/// Replica index range `[lo, hi)` of the decode pool.
pub(crate) fn decode_range(pl: &PoolConfig) -> (usize, usize) {
    (pl.prefill, pl.prefill + pl.decode)
}

/// Router pick range for a stage (full fleet for colocated work; a
/// transfer has no live delivery, so its range is moot but total).
pub(crate) fn stage_range(pl: &PoolConfig, stage: Stage, n: usize) -> (usize, usize) {
    if !pl.enabled() {
        return (0, n);
    }
    match stage {
        Stage::Colocated | Stage::Transfer => (0, n),
        Stage::Prefill => prefill_range(pl),
        Stage::Decode => decode_range(pl),
    }
}

/// CPU-side KV copy cost for one prompt: fixed setup plus bytes over
/// the interconnect, grounded in the model's actual per-token KV
/// footprint (`2 × layers × kv_heads × head_dim × dtype_bytes`).
pub(crate) fn transfer_cost_ns(
    pl: &PoolConfig,
    model: &crate::config::ModelSpec,
    prompt_tokens: u64,
) -> u64 {
    let bytes = prompt_tokens as f64 * model.kv_bytes_per_token() as f64;
    let wire_s = bytes / (pl.transfer_gb_per_s * 1e9);
    ((pl.transfer_base_s + wire_s) * 1e9) as u64
}

/// Is the decode pool saturated? Live deliveries on decode replicas
/// plus in-flight transfers (KV already committed to arrive) against
/// the configured per-replica ceiling.
pub(crate) fn decode_saturated(ctl: &super::FleetCtl, pl: &PoolConfig) -> bool {
    let (lo, hi) = decode_range(pl);
    let inflight: u64 = ctl.replicas[lo..hi].iter().map(|r| r.inflight).sum();
    let cap = (pl.max_inflight_per_decode * (hi - lo)) as u64;
    inflight + ctl.pools.transfers.len() as u64 >= cap
}

/// Close of a probe window: derive pool health from the member
/// replicas' (individually hysteresis-filtered) states and flip
/// colocated-fallback mode when a whole pool is Down.
pub(crate) fn refresh_mode(fs: &FleetShared) {
    let pl = &fs.fleet.pools;
    if !pl.enabled() {
        return;
    }
    let ctl = &mut *fs.ctl.borrow_mut();
    let all_down = |(lo, hi): (usize, usize)| {
        ctl.replicas[lo..hi].iter().all(|r| r.health == HealthState::Down)
    };
    let down = all_down(prefill_range(pl)) || all_down(decode_range(pl));
    ctl.pools.colocated_mode = down;
    if down {
        ctl.pools.stats.colocated_windows += 1;
    }
}

/// Primary dispatch of a new (or deferred) origin in a pools-enabled
/// fleet: decide its stage, apply backpressure, and place it.
pub(crate) fn route_disagg(sim: &mut Sim, fs: &FleetShared, fo: u64) {
    let pl = &fs.fleet.pools;
    let pick = {
        let ctl = &mut *fs.ctl.borrow_mut();
        let Some(st) = ctl.origins.get(&fo) else { return };
        let content_seed = st.arrival.content_seed;
        let disagg = !ctl.pools.colocated_mode && st.arrival.max_new_tokens > 1;
        if disagg && decode_saturated(ctl, pl) {
            // Decode pool full: throttle prefill by one router tick
            // rather than piling KV onto a saturated pool.
            ctl.pools.stats.backpressure_deferrals += 1;
            let defer = fs.pool_calls.borrow().as_ref().expect("pool calls installed").defer.clone();
            sim.call_at_shared(sim.now_ns() + fs.tick_ns, defer, fo);
            return;
        }
        let stage = if disagg {
            Stage::Prefill
        } else {
            if ctl.pools.colocated_mode && st.arrival.max_new_tokens > 1 {
                ctl.pools.stats.colocated_fallbacks += 1;
            }
            Stage::Colocated
        };
        let n = ctl.replicas.len();
        let Some(st) = ctl.origins.get_mut(&fo) else { return };
        st.stage = stage;
        let (lo, hi) = stage_range(pl, stage, n);
        router::pick_in(ctl, &fs.fleet, fo, content_seed, None, false, lo, hi)
    };
    if let Some(r) = pick {
        super::dispatch(sim, fs, fo, r, Arm::Primary);
    }
}

/// Begin the KV handoff for `fo` whose prefill leg just completed on
/// replica `src`.
pub(crate) fn begin_handoff(sim: &mut Sim, fs: &FleetShared, fo: u64, src: usize) {
    let now = sim.now_ns();
    {
        let ctl = &mut *fs.ctl.borrow_mut();
        ctl.pools.transfers.insert(
            fo,
            Transfer { src, attempt: 0, started_ns: now, launched_ns: now },
        );
        ctl.pools.stats.handoffs_started += 1;
    }
    launch_attempt(sim, fs, fo);
}

/// Shared-call target for a transfer retry after backoff: the entry
/// still being present is the liveness check (a cleared ledger — e.g.
/// the streaming horizon — silently cancels the retry).
pub(crate) fn retry_transfer(sim: &mut Sim, fs: &FleetShared, fo: u64) {
    launch_attempt(sim, fs, fo);
}

/// Launch one transfer attempt: pay the copy cost (plus any
/// deterministic stall strike) as a task on the source replica's
/// tokenizer executor, then hand completion back to the router via the
/// shared `xfer_done` call.
fn launch_attempt(sim: &mut Sim, fs: &FleetShared, fo: u64) {
    let now = sim.now_ns();
    let (src, cost_ns) = {
        let ctl = &mut *fs.ctl.borrow_mut();
        let prompt = match ctl.origins.get(&fo) {
            Some(st) => st.arrival.prompt_tokens,
            None => {
                ctl.pools.transfers.remove(&fo);
                return;
            }
        };
        let Some(t) = ctl.pools.transfers.get_mut(&fo) else { return };
        t.attempt += 1;
        t.launched_ns = now;
        let base = transfer_cost_ns(&fs.fleet.pools, &fs.envs[t.src].cfg.model, prompt);
        let stall = fs.envs[t.src]
            .faults
            .borrow()
            .transfer_stall_ns(now, fo, t.attempt as u64);
        (t.src, base.saturating_add(stall))
    };
    let done = fs.pool_calls.borrow().as_ref().expect("pool calls installed").xfer_done.clone();
    fs.envs[src].pool.submit_external(
        sim,
        TokJob {
            cost_ns,
            // KV-copy tasks are control-plane work, not a request's
            // encode: they never jump a priority-armed backlog.
            priority: 0,
            // +1 ns: completion re-enters the router in its own event
            // batch, mirroring the retry-backoff clamp.
            on_done: Box::new(move |ctx| {
                let at = ctx.now_ns() + 1;
                ctx.call_at_shared(at, done.clone(), fo);
            }),
        },
    );
}

/// A transfer attempt's copy task finished: decide lost-vs-landed by
/// the pure-hash loss rule, then retry, fall back to re-prefill, or
/// deliver the decode leg.
pub(crate) fn transfer_done(sim: &mut Sim, fs: &FleetShared, fo: u64) {
    let pl = &fs.fleet.pools;
    let now = sim.now_ns();
    enum Next {
        Retry { backoff: u64 },
        Reprefill,
        Deliver { dst: usize, handoff_ns: u64 },
    }
    let next = {
        let ctl = &mut *fs.ctl.borrow_mut();
        let Some(t) = ctl.pools.transfers.get(&fo).copied() else { return };
        if ctl.origins.get(&fo).is_none() {
            ctl.pools.transfers.remove(&fo);
            return;
        }
        let lost = fs.envs[t.src]
            .faults
            .borrow()
            .transfer_lost(t.launched_ns, fo, t.attempt as u64);
        if lost {
            if t.attempt < pl.transfer_max_attempts {
                ctl.pools.stats.transfer_retries += 1;
                let res = &fs.envs[0].cfg.serve.resilience;
                Next::Retry { backoff: engine::retry_backoff_ns(res, ctl.seed, fo, t.attempt) }
            } else {
                ctl.pools.stats.transfer_failures += 1;
                Next::Reprefill
            }
        } else {
            ctl.pools.stats.handoffs_completed += 1;
            let (lo, hi) = decode_range(pl);
            let content_seed = ctl.origins[&fo].arrival.content_seed;
            match router::pick_in(ctl, &fs.fleet, fo, content_seed, None, true, lo, hi) {
                Some(dst) => Next::Deliver { dst, handoff_ns: now - t.started_ns },
                // No eligible decode replica (pool sick): the KV has
                // nowhere to land — redo the work where capacity is.
                None => Next::Reprefill,
            }
        }
    };
    match next {
        Next::Retry { backoff } => {
            let start = fs.pool_calls.borrow().as_ref().expect("pool calls installed").xfer_start.clone();
            sim.call_at_shared(now + backoff, start, fo);
        }
        Next::Reprefill => {
            let pick = {
                let ctl = &mut *fs.ctl.borrow_mut();
                ctl.pools.transfers.remove(&fo);
                ctl.pools.stats.reprefills += 1;
                let n = ctl.replicas.len();
                let Some(st) = ctl.origins.get_mut(&fo) else { return };
                // The decode pool re-runs the whole prompt; the stored
                // prefill tokenize span no longer describes the final
                // attempt.
                st.stage = Stage::Decode;
                st.prefill_tok_ns = None;
                let content_seed = st.arrival.content_seed;
                let (lo, hi) = stage_range(pl, Stage::Decode, n);
                router::pick_in(ctl, &fs.fleet, fo, content_seed, None, false, lo, hi)
            };
            if let Some(r) = pick {
                // Counts as a retry on the fleet ledger (attempts > 0).
                super::dispatch(sim, fs, fo, r, Arm::Primary);
            }
        }
        Next::Deliver { dst, handoff_ns } => {
            {
                let ctl = &mut *fs.ctl.borrow_mut();
                ctl.pools.transfers.remove(&fo);
            }
            super::dispatch_decode(sim, fs, fo, dst, handoff_ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn pools(prefill: usize, decode: usize) -> PoolConfig {
        PoolConfig { prefill, decode, ..PoolConfig::default() }
    }

    #[test]
    fn ranges_partition_the_fleet() {
        let pl = pools(2, 3);
        assert_eq!(prefill_range(&pl), (0, 2));
        assert_eq!(decode_range(&pl), (2, 5));
        assert_eq!(stage_range(&pl, Stage::Prefill, 5), (0, 2));
        assert_eq!(stage_range(&pl, Stage::Decode, 5), (2, 5));
        assert_eq!(stage_range(&pl, Stage::Colocated, 5), (0, 5));
        // Pools off → every stage sees the whole fleet.
        let off = PoolConfig::default();
        assert_eq!(stage_range(&off, Stage::Prefill, 4), (0, 4));
    }

    #[test]
    fn transfer_cost_scales_with_prompt_and_model() {
        let pl = pools(1, 1);
        let m = ModelSpec::llama31_8b();
        let short = transfer_cost_ns(&pl, &m, 100);
        let long = transfer_cost_ns(&pl, &m, 1000);
        assert!(long > short, "more KV takes longer: {short} vs {long}");
        // base_s alone bounds the zero-token cost.
        let base = transfer_cost_ns(&pl, &m, 0);
        assert_eq!(base, (pl.transfer_base_s * 1e9) as u64);
        // Bandwidth matters: 10× the wire speed, under 10× the time.
        let fast = PoolConfig { transfer_gb_per_s: pl.transfer_gb_per_s * 10.0, ..pl };
        assert!(transfer_cost_ns(&fast, &m, 1000) < long);
    }

    #[test]
    fn pool_summary_defaults_to_zero() {
        let s = PoolSummary::default();
        assert_eq!(s, PoolSummary { ..Default::default() });
        assert_eq!(s.handoffs_started, 0);
        assert_eq!(s.reprefills, 0);
    }
}
