//! CPU-affinity restriction (Linux): confine the whole process to N
//! cores to emulate a constrained cluster allocation — the Track-R
//! analogue of the paper's "16 CPU cores on a 4×H100 node" setup (§III).

use anyhow::{bail, Result};

/// Restrict the calling process (all threads created *after* this call
/// inherit the mask) to cores `[0, n)`.
pub fn restrict_to_cores(n: usize) -> Result<()> {
    if n == 0 {
        bail!("cannot restrict to zero cores");
    }
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        let avail = available_cores();
        if n > avail {
            bail!("requested {n} cores but only {avail} online");
        }
        for cpu in 0..n {
            libc::CPU_SET(cpu, &mut set);
        }
        let rc = libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
        if rc != 0 {
            bail!("sched_setaffinity failed: {}", std::io::Error::last_os_error());
        }
    }
    Ok(())
}

/// Number of cores currently allowed by the process affinity mask.
pub fn allowed_cores() -> usize {
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        if libc::sched_getaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &mut set) != 0 {
            return available_cores();
        }
        libc::CPU_COUNT(&set) as usize
    }
}

/// Online core count.
pub fn available_cores() -> usize {
    unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) as usize }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_cores() {
        assert!(available_cores() >= 1);
        assert!(allowed_cores() >= 1);
        assert!(allowed_cores() <= available_cores());
    }

    #[test]
    fn rejects_zero() {
        assert!(restrict_to_cores(0).is_err());
    }

    #[test]
    fn rejects_more_than_available() {
        assert!(restrict_to_cores(available_cores() + 64).is_err());
    }
    // NOTE: actually *applying* a restriction is done only in examples —
    // tests must not constrain the whole test-runner process.
}
