//! Track R: the real mini serving stack.
//!
//! Everything on the request path is real and Rust: the BPE tokenizer
//! ([`crate::tokenizer`]) encodes prompts on a worker pool, the
//! [`crate::runtime::ModelRuntime`] executes the AOT-compiled JAX/Pallas
//! transformer via PJRT-CPU with continuous batching over
//! `decode_batch` lanes, and greedy sampling + detokenization close the
//! loop. Python never runs.
//!
//! This is the end-to-end validation vehicle (examples/serve_e2e.rs):
//! real tokens in, real logits out, measured TTFT/TPOT/throughput — and
//! with `affinity::restrict_to_cores(n)` it demonstrates the paper's
//! CPU-contention effect on this host for real.

pub mod affinity;

use crate::runtime::{DecodeState, ModelRuntime};
use crate::tokenizer::{BatchTokenizer, TokenId, Vocab};
use anyhow::{bail, Result};
use std::time::Instant;

/// Per-request timing and output record.
#[derive(Debug, Clone)]
pub struct RealOutcome {
    pub id: usize,
    pub prompt_chars: usize,
    pub prompt_tokens: usize,
    pub tokenize_s: f64,
    /// Time from submission to the first generated token.
    pub ttft_s: f64,
    /// Mean per-output-token latency after the first.
    pub tpot_s: f64,
    pub e2e_s: f64,
    pub generated: usize,
    pub text: String,
}

pub struct RealEngineConfig {
    pub max_new_tokens: usize,
    /// Tokenizer pool width (HF-style parallel encodes).
    pub tokenizer_threads: usize,
}

impl Default for RealEngineConfig {
    fn default() -> Self {
        RealEngineConfig {
            max_new_tokens: 16,
            tokenizer_threads: 4,
        }
    }
}

pub struct RealEngine {
    runtime: ModelRuntime,
    tokenizer: BatchTokenizer,
    cfg: RealEngineConfig,
}

impl RealEngine {
    pub fn new(artifacts_dir: &str, vocab: Vocab, cfg: RealEngineConfig) -> Result<RealEngine> {
        let runtime = ModelRuntime::load(artifacts_dir)?;
        if (vocab.size() as usize) > runtime.manifest().vocab {
            bail!(
                "tokenizer vocab {} exceeds model vocab {}",
                vocab.size(),
                runtime.manifest().vocab
            );
        }
        let tokenizer = BatchTokenizer::new(vocab, cfg.tokenizer_threads);
        Ok(RealEngine {
            runtime,
            tokenizer,
            cfg,
        })
    }

    pub fn manifest_summary(&self) -> String {
        let m = self.runtime.manifest();
        format!(
            "tiny-100M: {} params, {} layers, vocab {}, decode batch {}, buckets {:?}",
            m.n_params, m.n_layers, m.vocab, m.decode_batch, m.prefill_buckets
        )
    }

    /// Serve a batch of prompts with continuous batching over the decode
    /// lanes. Returns outcomes in submission order.
    pub fn serve(&self, prompts: Vec<String>) -> Result<Vec<RealOutcome>> {
        let t0 = Instant::now();
        let n = prompts.len();
        let max_prompt = self
            .runtime
            .manifest()
            .prefill_buckets
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
            .min(self.runtime.manifest().max_seq - self.cfg.max_new_tokens - 1);

        // 1. tokenize (real BPE, parallel pool) — timed per request
        let tok_start = Instant::now();
        let encoded = self.tokenizer.encode_batch_refs(&prompts);
        let tokenize_wall = tok_start.elapsed().as_secs_f64();
        let mut token_lists: Vec<Vec<TokenId>> = Vec::with_capacity(n);
        for ids in encoded {
            if ids.is_empty() {
                bail!("empty prompt after tokenization");
            }
            let mut ids = ids;
            ids.truncate(max_prompt);
            token_lists.push(ids);
        }

        // 2. continuous batching over decode lanes
        let batch = self.runtime.manifest().decode_batch;
        let mut state: DecodeState = self.runtime.new_decode_state()?;
        #[derive(Clone)]
        struct Lane {
            req: usize,
            next_token: i32,
            generated: Vec<TokenId>,
            first_token_at: Option<f64>,
            started_decode: bool,
        }
        let mut lanes: Vec<Option<Lane>> = vec![None; batch];
        let mut queue: std::collections::VecDeque<usize> = (0..n).collect();
        let mut outcomes: Vec<Option<RealOutcome>> = (0..n).map(|_| None).collect();
        let mut done = 0;

        while done < n {
            // admit waiting requests into free lanes (prefill = real PJRT)
            for lane_idx in 0..batch {
                if lanes[lane_idx].is_none() {
                    let Some(req) = queue.pop_front() else { break };
                    let toks = &token_lists[req];
                    // cache positions 0..len-1 via prefill; the last prompt
                    // token goes through the decode path to produce the
                    // first new-token logits.
                    if toks.len() > 1 {
                        let prefill = self.runtime.prefill(&toks[..toks.len() - 1])?;
                        self.runtime
                            .insert_lane(&mut state, lane_idx, &prefill, toks.len() - 1)?;
                    } else {
                        state.lengths[lane_idx] = 0;
                    }
                    lanes[lane_idx] = Some(Lane {
                        req,
                        next_token: *toks.last().unwrap() as i32,
                        generated: Vec::new(),
                        first_token_at: None,
                        started_decode: false,
                    });
                }
            }
            // batched decode step (real PJRT)
            let mut tokens = vec![0i32; batch];
            let mut active = vec![false; batch];
            for (i, lane) in lanes.iter().enumerate() {
                if let Some(l) = lane {
                    tokens[i] = l.next_token;
                    active[i] = true;
                }
            }
            if !active.iter().any(|&a| a) {
                bail!("deadlock: no active lanes with {} waiting", queue.len());
            }
            let logits = self.runtime.decode_step(&mut state, &tokens, &active)?;
            let now_s = t0.elapsed().as_secs_f64();
            // Sample only within the tokenizer's vocabulary (the model's
            // vocab dim is padded up to a power of two).
            let vocab_limit = self.tokenizer.vocab().size();
            for lane_idx in 0..batch {
                let Some(lane) = &mut lanes[lane_idx] else { continue };
                let next = ModelRuntime::argmax(&logits[lane_idx][..vocab_limit]) as TokenId;
                lane.generated.push(next);
                lane.next_token = next as i32;
                lane.started_decode = true;
                if lane.first_token_at.is_none() {
                    lane.first_token_at = Some(now_s);
                }
                if lane.generated.len() >= self.cfg.max_new_tokens {
                    // finish request
                    let lane = lanes[lane_idx].take().unwrap();
                    let req = lane.req;
                    let e2e = t0.elapsed().as_secs_f64();
                    let ttft = lane.first_token_at.unwrap();
                    let tpot = if lane.generated.len() > 1 {
                        (e2e - ttft) / (lane.generated.len() - 1) as f64
                    } else {
                        0.0
                    };
                    let text = crate::tokenizer::decode(self.tokenizer.vocab(), &lane.generated);
                    outcomes[req] = Some(RealOutcome {
                        id: req,
                        prompt_chars: prompts[req].len(),
                        prompt_tokens: token_lists[req].len(),
                        tokenize_s: tokenize_wall, // batch-level wall time
                        ttft_s: ttft,
                        tpot_s: tpot,
                        e2e_s: e2e,
                        generated: lane.generated.len(),
                        text,
                    });
                    done += 1;
                    state.lengths[lane_idx] = 0;
                }
            }
        }
        Ok(outcomes.into_iter().map(|o| o.unwrap()).collect())
    }

    /// Aggregate throughput stats over a serve() result.
    pub fn summarize(outcomes: &[RealOutcome]) -> (f64, f64, f64) {
        let n = outcomes.len().max(1) as f64;
        let mean_ttft = outcomes.iter().map(|o| o.ttft_s).sum::<f64>() / n;
        let total_tokens: usize = outcomes.iter().map(|o| o.generated).sum();
        let makespan = outcomes.iter().map(|o| o.e2e_s).fold(0.0, f64::max);
        let tput = total_tokens as f64 / makespan.max(1e-9);
        (mean_ttft, tput, makespan)
    }
}
