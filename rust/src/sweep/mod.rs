//! Parallel sweep executor.
//!
//! Every paper figure is a grid of *independent* simulation cells
//! (model × GPUs × RPS × SL × cores …) that the experiment harnesses
//! used to run strictly one after another. This module represents an
//! experiment as a flat cell list and fans the cells across the
//! [`ThreadPool`](crate::util::pool::ThreadPool):
//!
//! * **Deterministic ordering** — results come back in input order no
//!   matter which worker finishes first, so tables/CSV/JSON are
//!   byte-identical between `--jobs 1` and `--jobs N`.
//! * **Deterministic seeding** — for sweeps that need randomness,
//!   [`seeded_cells`] derives a per-cell seed from (base seed, cell
//!   index) via SplitMix64, never from the execution schedule. The
//!   serve-sweep scenario grid consumes these: each cell expands its
//!   scenario into a trace from its per-index seed, so randomized
//!   workloads stay byte-identical across `--jobs` values. (The paper
//!   figure grids remain pure functions of their specs.)
//! * **Progress** — a single `\r`-rewritten progress line on *stderr*
//!   (stdout is reserved for the figure tables).
//!
//! The `--jobs N` CLI flag selects the fan-out width; the default is
//! the host's available parallelism, and `--jobs 1` reproduces the old
//! serial runner exactly (same thread, same order).

use crate::util::cli::Args;
use crate::util::pool::ThreadPool;
use crate::util::rng::SplitMix64;
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Resolve the `--jobs N` flag; 0 or absent means "all cores".
pub fn jobs_from_args(args: &Args) -> usize {
    match args.usize_or("jobs", 0) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
}

/// One cell of a sweep with its deterministic seed.
#[derive(Debug, Clone)]
pub struct SeededCell<I> {
    /// Position in the experiment's cell list (== result position).
    pub index: usize,
    /// Derived from (base seed, index) only — stable across schedules.
    pub seed: u64,
    pub input: I,
}

/// Attach per-cell seeds to a cell list.
pub fn seeded_cells<I>(base_seed: u64, inputs: Vec<I>) -> Vec<SeededCell<I>> {
    inputs
        .into_iter()
        .enumerate()
        .map(|(index, input)| {
            // Two SplitMix64 steps decorrelate adjacent indices fully.
            let mut sm = SplitMix64::new(base_seed.wrapping_add(index as u64));
            sm.next_u64();
            SeededCell {
                index,
                seed: sm.next_u64(),
                input,
            }
        })
        .collect()
}

/// A configured sweep: label (for the progress line) + fan-out width.
pub struct Sweep {
    label: String,
    jobs: usize,
    progress: bool,
}

impl Sweep {
    pub fn new(label: &str, jobs: usize) -> Sweep {
        Sweep {
            label: label.to_string(),
            jobs: jobs.max(1),
            progress: true,
        }
    }

    /// Standard construction for experiment harnesses: width from
    /// `--jobs`, progress suppressed by `--no-progress`.
    pub fn from_args(label: &str, args: &Args) -> Sweep {
        Sweep::new(label, jobs_from_args(args)).quiet(args.flag("no-progress"))
    }

    pub fn quiet(mut self, quiet: bool) -> Sweep {
        self.progress = !quiet;
        self
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run every cell and return results in input order. `run_cell` must
    /// be a pure function of its cell (all the experiment cells are:
    /// each builds its own `Sim` from the spec).
    pub fn run<I, R, F>(&self, cells: Vec<I>, run_cell: F) -> Vec<R>
    where
        I: Send + 'static,
        R: Send + 'static,
        F: Fn(I) -> R + Send + Sync + 'static,
    {
        let total = cells.len();
        if total == 0 {
            return Vec::new();
        }
        let jobs = self.jobs.min(total);
        let t0 = Instant::now();
        let done = Arc::new(AtomicUsize::new(0));
        let progress = self.progress;
        let label = self.label.clone();
        let tick = {
            let done = Arc::clone(&done);
            move || {
                let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                if progress {
                    let mut err = std::io::stderr().lock();
                    let _ = write!(err, "\r{label}: {d}/{total} cells (jobs={jobs})");
                    if d == total {
                        let _ = writeln!(err, " — {:.1}s", t0.elapsed().as_secs_f64());
                    }
                    let _ = err.flush();
                }
            }
        };
        if jobs <= 1 {
            // Serial fast path: same thread, same order as the old
            // per-experiment loops.
            cells
                .into_iter()
                .map(|cell| {
                    let r = run_cell(cell);
                    tick();
                    r
                })
                .collect()
        } else {
            // parallel_map Arc-wraps the closure itself; `tick` rides
            // along inside it (all its captures are Sync).
            let pool = ThreadPool::new(jobs);
            pool.parallel_map(cells, move |cell| {
                let r = run_cell(cell);
                tick();
                r
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(label: &str, jobs: usize) -> Sweep {
        Sweep::new(label, jobs).quiet(true)
    }

    #[test]
    fn results_preserve_input_order() {
        let inputs: Vec<u64> = (0..64).collect();
        let out = quiet("order", 8).run(inputs, |i| {
            // stagger so later cells tend to finish first
            std::thread::sleep(std::time::Duration::from_micros(((64 - i) % 7) * 100));
            i * 3
        });
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<u64>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let f = |i: u64| i * i + 1;
        let a = quiet("serial", 1).run((0..100).collect(), f);
        let b = quiet("parallel", 4).run((0..100).collect(), f);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_sweep() {
        let out: Vec<u64> = quiet("empty", 4).run(Vec::<u64>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn seeds_depend_on_index_not_schedule() {
        let a = seeded_cells(42, vec!["a", "b", "c"]);
        let b = seeded_cells(42, vec!["a", "b", "c"]);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.index, y.index);
        }
        assert_ne!(a[0].seed, a[1].seed);
        let c = seeded_cells(43, vec!["a"]);
        assert_ne!(a[0].seed, c[0].seed);
    }

    #[test]
    fn jobs_flag_parses() {
        let parse = |s: &str| crate::util::cli::Args::parse(s.split_whitespace().map(String::from));
        assert_eq!(jobs_from_args(&parse("x --jobs 3")), 3);
        let auto = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        assert_eq!(jobs_from_args(&parse("x")), auto);
        assert_eq!(jobs_from_args(&parse("x --jobs 0")), auto);
    }
}
