//! Model architecture specifications.
//!
//! The paper evaluates Llama 3.1 8B and Qwen 2.5 14B; the real-execution
//! track uses `tiny_100m`, the transformer actually compiled by the
//! JAX/Pallas layer. Parameter counts and FLOP estimates feed the GPU
//! roofline model.

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (grouped-query attention).
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    /// Bytes per parameter/activation element (2 = bf16).
    pub dtype_bytes: usize,
    pub max_seq_len: usize,
}

impl ModelSpec {
    pub fn llama31_8b() -> ModelSpec {
        ModelSpec {
            name: "Llama-3.1-8B".into(),
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            d_ff: 14336,
            vocab_size: 128_256,
            dtype_bytes: 2,
            max_seq_len: 131_072,
        }
    }

    pub fn qwen25_14b() -> ModelSpec {
        ModelSpec {
            name: "Qwen-2.5-14B".into(),
            n_layers: 48,
            d_model: 5120,
            n_heads: 40,
            n_kv_heads: 8,
            d_ff: 13_824,
            vocab_size: 152_064,
            dtype_bytes: 2,
            max_seq_len: 131_072,
        }
    }

    /// The real model compiled by python/compile and served in Track R.
    /// ~100 M parameters — large enough to be a genuine workload on a CPU
    /// PJRT backend, small enough to compile and run everywhere.
    pub fn tiny_100m() -> ModelSpec {
        ModelSpec {
            name: "tiny-100M".into(),
            n_layers: 12,
            d_model: 768,
            n_heads: 12,
            n_kv_heads: 12,
            d_ff: 3072,
            vocab_size: 8192,
            dtype_bytes: 4, // f32 on the CPU PJRT backend
            max_seq_len: 2048,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name
            .to_ascii_lowercase()
            .replace(['-', '_', '.', ' '], "")
            .as_str()
        {
            "llama318b" | "llama8b" | "llama" => Some(Self::llama31_8b()),
            "qwen2514b" | "qwen14b" | "qwen" => Some(Self::qwen25_14b()),
            "tiny100m" | "tiny" => Some(Self::tiny_100m()),
            _ => None,
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + per-layer attention/MLP +
    /// final norm + LM head, assuming untied embeddings).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let dff = self.d_ff as u64;
        let v = self.vocab_size as u64;
        let kv_frac = self.n_kv_heads as u64 * self.d_head() as u64;
        // attention: Wq (d×d), Wk/Wv (d×kv), Wo (d×d)
        let attn = d * d + 2 * d * kv_frac + d * d;
        // SwiGLU MLP: gate + up (d×dff each) + down (dff×d)
        let mlp = 3 * d * dff;
        let per_layer = attn + mlp + 2 * d; // + 2 norms
        self.n_layers as u64 * per_layer + 2 * v * d + d
    }

    /// FLOPs for one forward pass over `n_tokens` new tokens given
    /// `ctx_len` total context (prefill: n_tokens = ctx; decode: 1).
    /// 2·params·tokens for the dense part plus attention score FLOPs.
    pub fn forward_flops(&self, n_tokens: u64, ctx_len: u64) -> f64 {
        let dense = 2.0 * self.param_count() as f64 * n_tokens as f64;
        // attention: 2 matmuls of [n_tokens × ctx] × d per layer, ×2 FLOPs
        let attn = 4.0
            * self.n_layers as f64
            * n_tokens as f64
            * ctx_len as f64
            * self.d_model as f64;
        dense + attn
    }

    /// Bytes of weights read for one decode step (the memory-bound side
    /// of the roofline) — all parameters once, plus the KV cache.
    pub fn decode_bytes(&self, ctx_len: u64, batch: u64) -> f64 {
        let weights = self.param_count() as f64 * self.dtype_bytes as f64;
        let kv = self.kv_bytes_per_token() as f64 * ctx_len as f64 * batch as f64;
        weights + kv
    }

    /// KV-cache bytes per token (all layers, K+V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.n_kv_heads * self.d_head() * self.dtype_bytes) as u64
    }

    /// Kernel launches per transformer layer per step. Roughly: qkv proj,
    /// rope, attention, out proj, 2 norms, 3 mlp matmuls, activation,
    /// residual adds ≈ 12 compute kernels + 1 collective per layer under
    /// tensor parallelism (2 allreduces per layer halved by fusing).
    pub fn kernels_per_layer(&self) -> usize {
        12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_param_count_close_to_8b() {
        let p = ModelSpec::llama31_8b().param_count();
        assert!(
            (7.5e9..9.0e9).contains(&(p as f64)),
            "Llama-3.1-8B params = {p}"
        );
    }

    #[test]
    fn qwen_param_count_close_to_14b() {
        let p = ModelSpec::qwen25_14b().param_count();
        assert!(
            (13.0e9..16.0e9).contains(&(p as f64)),
            "Qwen-2.5-14B params = {p}"
        );
    }

    #[test]
    fn tiny_is_about_100m() {
        let p = ModelSpec::tiny_100m().param_count();
        assert!(
            (6.0e7..1.5e8).contains(&(p as f64)),
            "tiny params = {p}"
        );
    }

    #[test]
    fn lookups() {
        assert_eq!(ModelSpec::by_name("llama-3.1-8b").unwrap().name, "Llama-3.1-8B");
        assert_eq!(ModelSpec::by_name("qwen14b").unwrap().name, "Qwen-2.5-14B");
        assert_eq!(ModelSpec::by_name("tiny").unwrap().name, "tiny-100M");
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn prefill_flops_scale_superlinearly() {
        let m = ModelSpec::llama31_8b();
        let f1 = m.forward_flops(1_000, 1_000);
        let f2 = m.forward_flops(2_000, 2_000);
        assert!(f2 > 2.0 * f1); // quadratic attention term present
        assert!(f2 < 4.0 * f1); // but dense-dominated at these lengths
    }

    #[test]
    fn decode_is_memory_bound_shape() {
        let m = ModelSpec::llama31_8b();
        // decode bytes grow with context (KV reads)
        assert!(m.decode_bytes(100_000, 1) > m.decode_bytes(1_000, 1));
        // one decode step FLOPs are tiny relative to prefill
        assert!(m.forward_flops(1, 4096) < m.forward_flops(4096, 4096) / 1000.0);
    }

    #[test]
    fn kv_bytes_gqa() {
        let m = ModelSpec::llama31_8b();
        // 2 × 32 layers × 8 kv heads × 128 dhead × 2 bytes = 131072
        assert_eq!(m.kv_bytes_per_token(), 131_072);
    }
}
