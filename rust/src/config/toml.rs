//! TOML-subset parser for user-supplied config files.
//!
//! Supports: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / array-of-scalar values, `#`
//! comments. That covers everything a `cpuslow.toml` needs; nested tables
//! beyond two levels, dates, and multi-line strings are rejected loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section path ("" for root, "a.b" for nested) → keys.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(|v| v.as_float())
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .and_then(|v| v.as_bool())
            .unwrap_or(default)
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    doc.sections.insert(String::new(), BTreeMap::new());
    let mut current = String::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err("empty section name"));
            }
            if name.starts_with('[') {
                return Err(err("array-of-tables not supported"));
            }
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
        } else {
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(value.trim()).map_err(|m| err(&m))?;
            doc.sections
                .get_mut(&current)
                .unwrap()
                .insert(key.to_string(), value);
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".to_string());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape: \\{other:?}")),
                }
            } else if c == '"' {
                return Err("unescaped quote inside string".to_string());
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items: Result<Vec<TomlValue>, String> = split_array_items(inner)
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect();
        return Ok(TomlValue::Array(items?));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

fn split_array_items(inner: &str) -> Vec<&str> {
    // split on top-level commas (no nested arrays of arrays supported,
    // but strings with commas are respected)
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&inner[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse(
            r#"
# experiment config
seed = 42

[system]
name = "blackwell"   # Table I row 3
cpu_cores = 16
gpu_efficiency = 0.4

[serve]
cuda_graphs = true
core_levels = [5, 8, 16, 32]
"#,
        )
        .unwrap();
        assert_eq!(doc.int_or("", "seed", 0), 42);
        assert_eq!(doc.str_or("system", "name", ""), "blackwell");
        assert_eq!(doc.int_or("system", "cpu_cores", 0), 16);
        assert!((doc.float_or("system", "gpu_efficiency", 0.0) - 0.4).abs() < 1e-12);
        assert!(doc.bool_or("serve", "cuda_graphs", false));
        let arr = doc.get("serve", "core_levels").unwrap();
        if let TomlValue::Array(items) = arr {
            let ints: Vec<i64> = items.iter().map(|v| v.as_int().unwrap()).collect();
            assert_eq!(ints, vec![5, 8, 16, 32]);
        } else {
            panic!("not an array");
        }
    }

    #[test]
    fn nested_section_names() {
        let doc = parse("[a.b]\nx = 1\n").unwrap();
        assert_eq!(doc.int_or("a.b", "x", 0), 1);
    }

    #[test]
    fn string_escapes_and_hash_in_string() {
        let doc = parse("s = \"a#b\\nc\"\n").unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a#b\nc");
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("n = 114_000\n").unwrap();
        assert_eq!(doc.int_or("", "n", 0), 114_000);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("x = \"open\n").is_err());
    }

    #[test]
    fn float_parsing() {
        let doc = parse("x = 1.5e-6\ny = 3\n").unwrap();
        assert!((doc.float_or("", "x", 0.0) - 1.5e-6).abs() < 1e-18);
        // ints coerce to float on demand
        assert_eq!(doc.float_or("", "y", 0.0), 3.0);
    }
}
