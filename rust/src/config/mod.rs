//! Typed configuration system.
//!
//! Presets encode the paper's Table I machines and the evaluated models;
//! a TOML-subset parser (`toml.rs`) lets users define their own systems
//! and serving configs in files, as a real framework would.

pub mod model;
pub mod serve;
pub mod system;
pub mod toml;

pub use model::ModelSpec;
pub use serve::{
    FleetConfig, PoolConfig, PriorityConfig, ResilienceConfig, RouterPolicy, ServeConfig,
    WorkloadConfig, MAX_RETRY_ATTEMPTS,
};
pub use system::{Interconnect, SystemSpec};

use anyhow::{bail, Result};

/// Causal what-if cost multipliers (`cpuslow whatif`, COZ-style causal
/// profiling): each factor virtually scales one component's simulated
/// cost. The default of 1.0 is an *exact* no-op — the engine applies
/// each factor as `(cost as f64 * factor) as u64`, and IEEE 754
/// guarantees `x * 1.0 == x` — so baseline runs are byte-identical to
/// runs that never consult the scales at all.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostScales {
    /// Tokenization CPU cost per request.
    pub tokenize: f64,
    /// CPU-side kernel-launch cost per step.
    pub launch: f64,
    /// Collective-communication (allreduce) cost per step.
    pub comm: f64,
    /// GPU compute cost per step.
    pub compute: f64,
}

impl Default for CostScales {
    fn default() -> Self {
        CostScales {
            tokenize: 1.0,
            launch: 1.0,
            comm: 1.0,
            compute: 1.0,
        }
    }
}

impl CostScales {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("tokenize", self.tokenize),
            ("launch", self.launch),
            ("comm", self.comm),
            ("compute", self.compute),
        ] {
            if !v.is_finite() || v <= 0.0 {
                bail!("scales.{name} must be finite and > 0 (got {v})");
            }
        }
        Ok(())
    }
}

/// A fully-resolved experiment configuration: which machine, which model,
/// how many GPUs, how many CPU cores, and the serving parameters.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub system: SystemSpec,
    pub model: ModelSpec,
    pub n_gpus: usize,
    pub cpu_cores: usize,
    pub serve: ServeConfig,
    pub workload: WorkloadConfig,
    pub seed: u64,
    /// What-if cost multipliers; all 1.0 (exact no-op) by default.
    pub scales: CostScales,
}

impl RunConfig {
    pub fn new(system: SystemSpec, model: ModelSpec, n_gpus: usize, cpu_cores: usize) -> Self {
        Self {
            system,
            model,
            n_gpus,
            cpu_cores,
            serve: ServeConfig::default(),
            workload: WorkloadConfig::default(),
            seed: 0,
            scales: CostScales::default(),
        }
    }

    /// Validate physical consistency before a run.
    pub fn validate(&self) -> Result<()> {
        if self.n_gpus == 0 {
            bail!("n_gpus must be ≥ 1");
        }
        if self.n_gpus > self.system.gpus_per_node {
            bail!(
                "requested {} GPUs but {} has {} per node",
                self.n_gpus,
                self.system.name,
                self.system.gpus_per_node
            );
        }
        if self.cpu_cores == 0 {
            bail!("cpu_cores must be ≥ 1");
        }
        if self.cpu_cores > self.system.cpu_cores {
            bail!(
                "requested {} cores but {} has {}",
                self.cpu_cores,
                self.system.name,
                self.system.cpu_cores
            );
        }
        if self.model.n_layers == 0 || self.model.d_model == 0 {
            bail!("degenerate model spec");
        }
        if self.model.n_heads % self.n_gpus != 0 {
            bail!(
                "tensor parallelism requires n_heads ({}) divisible by n_gpus ({})",
                self.model.n_heads,
                self.n_gpus
            );
        }
        self.serve.validate()?;
        self.workload.validate()?;
        self.scales.validate()?;
        Ok(())
    }

    /// The paper's four CPU provisioning levels for a given GPU count:
    /// (#GPUs + 1), 2×, 4×, 8× #GPUs (§IV-B "Experimental setup").
    pub fn paper_core_levels(n_gpus: usize) -> Vec<usize> {
        vec![n_gpus + 1, 2 * n_gpus, 4 * n_gpus, 8 * n_gpus]
    }

    /// Load a run configuration from a TOML file. Recognized keys:
    ///
    /// ```toml
    /// seed = 42
    /// [system]            # preset + overrides
    /// name = "blackwell"
    /// tokenize_us_per_token = 15.0
    /// gpu_efficiency = 0.4
    /// [run]
    /// model = "llama8b"
    /// gpus = 4
    /// cores = 16
    /// [serve]
    /// max_batch_size = 256
    /// prefill_chunk_tokens = 2048
    /// prefix_caching = true
    /// cuda_graphs = true
    /// tokenizer_threads = 0
    /// timeout_s = 200.0
    /// max_output_tokens = 32
    /// control_plane_weight = 1
    /// profile = false          # arm the attribution profiler
    /// [scales]                 # causal what-if cost multipliers (1.0 = exact no-op)
    /// tokenize = 1.0
    /// launch = 1.0
    /// comm = 1.0
    /// compute = 1.0
    /// [workload]
    /// scenario = "bursty"     # catalog name; see `cpuslow scenarios`
    /// duration_s = 60.0
    /// rate_scale = 1.5
    /// [resilience]
    /// admission_max_queue = 512   # 0 = off
    /// shed_slo_factor = 1.0       # 0.0 = off
    /// watchdog_slo_factor = 2.0   # 0.0 = off
    /// retry_max_attempts = 3      # 1 = no retry
    /// retry_base_s = 0.5
    /// retry_cap_s = 4.0
    /// [priority]
    /// scheduling = true           # priority admission + KV-pressure preemption
    /// tokenizer = true            # priority tokenize-job queue
    /// brownout = true             # graceful-degradation ladder
    /// brownout_window_s = 0.25
    /// brownout_down_after = 2
    /// brownout_up_after = 2
    /// brownout_slo_factor = 0.5
    /// brownout_output_cap = 8
    /// [fleet]
    /// replicas = 4                # 1 = fleet layer off
    /// router = "least-loaded"     # round-robin | least-loaded | prefix-affinity
    /// failure_aware = true
    /// hedge_delay_s = 0.0         # 0 = hedging off
    /// autoscale = false
    /// [fleet.pools]               # disaggregated prefill/decode pools
    /// prefill = 1                 # 0 = pools off (colocated fleet)
    /// decode = 3                  # prefill + decode must equal replicas
    /// transfer_gb_per_s = 25.0    # KV handoff copy bandwidth
    /// transfer_base_s = 0.0005    # per-transfer setup cost
    /// transfer_max_attempts = 3   # 1 = no transfer retry
    /// max_inflight_per_decode = 8 # backpressure gate
    /// ```
    pub fn from_toml_str(text: &str) -> Result<RunConfig> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let sys_name = doc.str_or("system", "name", "h100");
        let mut system = SystemSpec::by_name(&sys_name)
            .ok_or_else(|| anyhow::anyhow!("unknown system '{sys_name}'"))?;
        if let Some(v) = doc.get("system", "tokenize_us_per_token").and_then(|v| v.as_float()) {
            system.tokenize_s_per_token = v * 1e-6;
        }
        if let Some(v) = doc.get("system", "gpu_efficiency").and_then(|v| v.as_float()) {
            system.gpu_efficiency = v;
        }
        let model_name = doc.str_or("run", "model", "llama8b");
        let model = ModelSpec::by_name(&model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
        let n_gpus = doc.int_or("run", "gpus", 4) as usize;
        let cores = doc.int_or("run", "cores", (n_gpus + 1) as i64) as usize;
        let mut cfg = RunConfig::new(system, model, n_gpus, cores);
        cfg.seed = doc.int_or("", "seed", 0) as u64;
        let s = &mut cfg.serve;
        s.max_batch_size = doc.int_or("serve", "max_batch_size", s.max_batch_size as i64) as usize;
        s.prefill_chunk_tokens =
            doc.int_or("serve", "prefill_chunk_tokens", s.prefill_chunk_tokens as i64) as usize;
        s.prefix_caching = doc.bool_or("serve", "prefix_caching", s.prefix_caching);
        s.cuda_graphs = doc.bool_or("serve", "cuda_graphs", s.cuda_graphs);
        s.tokenizer_threads =
            doc.int_or("serve", "tokenizer_threads", s.tokenizer_threads as i64) as usize;
        s.timeout_s = doc.float_or("serve", "timeout_s", s.timeout_s);
        s.max_output_tokens =
            doc.int_or("serve", "max_output_tokens", s.max_output_tokens as i64) as usize;
        s.control_plane_weight =
            doc.int_or("serve", "control_plane_weight", s.control_plane_weight as i64) as u32;
        s.profile = doc.bool_or("serve", "profile", s.profile);
        let r = &mut s.resilience;
        r.admission_max_queue =
            doc.int_or("resilience", "admission_max_queue", r.admission_max_queue as i64) as usize;
        r.shed_slo_factor = doc.float_or("resilience", "shed_slo_factor", r.shed_slo_factor);
        r.watchdog_slo_factor =
            doc.float_or("resilience", "watchdog_slo_factor", r.watchdog_slo_factor);
        r.retry_max_attempts =
            doc.int_or("resilience", "retry_max_attempts", r.retry_max_attempts as i64) as u32;
        r.retry_base_s = doc.float_or("resilience", "retry_base_s", r.retry_base_s);
        r.retry_cap_s = doc.float_or("resilience", "retry_cap_s", r.retry_cap_s);
        let p = &mut s.priority;
        p.scheduling = doc.bool_or("priority", "scheduling", p.scheduling);
        p.tokenizer = doc.bool_or("priority", "tokenizer", p.tokenizer);
        p.brownout = doc.bool_or("priority", "brownout", p.brownout);
        p.brownout_window_s =
            doc.float_or("priority", "brownout_window_s", p.brownout_window_s);
        p.brownout_down_after =
            doc.int_or("priority", "brownout_down_after", p.brownout_down_after as i64) as u32;
        p.brownout_up_after =
            doc.int_or("priority", "brownout_up_after", p.brownout_up_after as i64) as u32;
        p.brownout_slo_factor =
            doc.float_or("priority", "brownout_slo_factor", p.brownout_slo_factor);
        p.brownout_output_cap =
            doc.int_or("priority", "brownout_output_cap", p.brownout_output_cap as i64) as u64;
        let fl = &mut s.fleet;
        fl.replicas = doc.int_or("fleet", "replicas", fl.replicas as i64) as usize;
        let router_name = doc.str_or("fleet", "router", fl.router.name());
        fl.router = serve::RouterPolicy::by_name(&router_name)
            .ok_or_else(|| anyhow::anyhow!("unknown fleet router '{router_name}'"))?;
        fl.failure_aware = doc.bool_or("fleet", "failure_aware", fl.failure_aware);
        fl.hedge_delay_s = doc.float_or("fleet", "hedge_delay_s", fl.hedge_delay_s);
        fl.failover_max_attempts =
            doc.int_or("fleet", "failover_max_attempts", fl.failover_max_attempts as i64) as u32;
        fl.probe_interval_s = doc.float_or("fleet", "probe_interval_s", fl.probe_interval_s);
        fl.probe_idle_bad_share =
            doc.float_or("fleet", "probe_idle_bad_share", fl.probe_idle_bad_share);
        fl.probe_shed_bad = doc.int_or("fleet", "probe_shed_bad", fl.probe_shed_bad as i64) as u32;
        fl.down_after = doc.int_or("fleet", "down_after", fl.down_after as i64) as u32;
        fl.recover_after = doc.int_or("fleet", "recover_after", fl.recover_after as i64) as u32;
        fl.drain_ramp_windows =
            doc.int_or("fleet", "drain_ramp_windows", fl.drain_ramp_windows as i64) as u32;
        fl.autoscale = doc.bool_or("fleet", "autoscale", fl.autoscale);
        fl.min_cores_per_replica =
            doc.int_or("fleet", "min_cores_per_replica", fl.min_cores_per_replica as i64) as usize;
        fl.max_cores_per_replica =
            doc.int_or("fleet", "max_cores_per_replica", fl.max_cores_per_replica as i64) as usize;
        fl.autoscale_idle_lo = doc.float_or("fleet", "autoscale_idle_lo", fl.autoscale_idle_lo);
        fl.autoscale_idle_hi = doc.float_or("fleet", "autoscale_idle_hi", fl.autoscale_idle_hi);
        fl.autoscale_every =
            doc.int_or("fleet", "autoscale_every", fl.autoscale_every as i64) as u32;
        let pl = &mut fl.pools;
        pl.prefill = doc.int_or("fleet.pools", "prefill", pl.prefill as i64) as usize;
        pl.decode = doc.int_or("fleet.pools", "decode", pl.decode as i64) as usize;
        pl.transfer_gb_per_s =
            doc.float_or("fleet.pools", "transfer_gb_per_s", pl.transfer_gb_per_s);
        pl.transfer_base_s = doc.float_or("fleet.pools", "transfer_base_s", pl.transfer_base_s);
        pl.transfer_max_attempts = doc
            .int_or("fleet.pools", "transfer_max_attempts", pl.transfer_max_attempts as i64)
            as u32;
        pl.max_inflight_per_decode = doc
            .int_or("fleet.pools", "max_inflight_per_decode", pl.max_inflight_per_decode as i64)
            as usize;
        let sc = &mut cfg.scales;
        sc.tokenize = doc.float_or("scales", "tokenize", sc.tokenize);
        sc.launch = doc.float_or("scales", "launch", sc.launch);
        sc.comm = doc.float_or("scales", "comm", sc.comm);
        sc.compute = doc.float_or("scales", "compute", sc.compute);
        let w = &mut cfg.workload;
        w.scenario = doc.str_or("workload", "scenario", "");
        w.rate_scale = doc.float_or("workload", "rate_scale", w.rate_scale);
        if let Some(v) = doc.get("workload", "duration_s").and_then(|v| v.as_float()) {
            w.duration_s = Some(v);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::from_toml_str(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_roundtrip_validates() {
        let cfg = RunConfig::new(
            SystemSpec::blackwell(),
            ModelSpec::llama31_8b(),
            4,
            16,
        );
        cfg.validate().unwrap();
    }

    #[test]
    fn rejects_too_many_gpus() {
        let cfg = RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 16, 8);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_zero_cores() {
        let cfg = RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 4, 0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_indivisible_tp() {
        // 32 heads / 5 GPUs does not divide
        let mut cfg = RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 4, 8);
        cfg.n_gpus = 5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = RunConfig::from_toml_str(
            r#"
seed = 7
[system]
name = "blackwell"
tokenize_us_per_token = 20.0
[run]
model = "qwen14b"
gpus = 8
cores = 16
[serve]
prefill_chunk_tokens = 4096
prefix_caching = false
control_plane_weight = 4
"#,
        )
        .unwrap();
        assert_eq!(cfg.system.name, "RTX Pro 6000");
        assert!((cfg.system.tokenize_s_per_token - 20e-6).abs() < 1e-12);
        assert_eq!(cfg.model.name, "Qwen-2.5-14B");
        assert_eq!(cfg.n_gpus, 8);
        assert_eq!(cfg.cpu_cores, 16);
        assert_eq!(cfg.serve.prefill_chunk_tokens, 4096);
        assert!(!cfg.serve.prefix_caching);
        assert_eq!(cfg.serve.control_plane_weight, 4);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn toml_rejects_invalid() {
        assert!(RunConfig::from_toml_str("[system]\nname = \"tpu\"\n").is_err());
        assert!(RunConfig::from_toml_str("[run]\ngpus = 99\n").is_err());
        assert!(RunConfig::from_toml_str("[workload]\nrate_scale = -2.0\n").is_err());
    }

    #[test]
    fn toml_workload_section() {
        let cfg = RunConfig::from_toml_str(
            "[workload]\nscenario = \"bursty\"\nduration_s = 30.0\nrate_scale = 2.0\n",
        )
        .unwrap();
        assert_eq!(cfg.workload.scenario, "bursty");
        assert_eq!(cfg.workload.duration_s, Some(30.0));
        assert_eq!(cfg.workload.rate_scale, 2.0);
        // absent section keeps defaults
        let cfg = RunConfig::from_toml_str("[run]\ngpus = 4\n").unwrap();
        assert_eq!(cfg.workload, WorkloadConfig::default());
    }

    #[test]
    fn toml_resilience_section() {
        let cfg = RunConfig::from_toml_str(
            "[resilience]\nadmission_max_queue = 512\nshed_slo_factor = 1.0\n\
             watchdog_slo_factor = 2.0\nretry_max_attempts = 3\nretry_base_s = 0.25\n\
             retry_cap_s = 4.0\n",
        )
        .unwrap();
        let r = &cfg.serve.resilience;
        assert_eq!(r.admission_max_queue, 512);
        assert_eq!(r.shed_slo_factor, 1.0);
        assert_eq!(r.watchdog_slo_factor, 2.0);
        assert_eq!(r.retry_max_attempts, 3);
        assert_eq!(r.retry_base_s, 0.25);
        assert_eq!(r.retry_cap_s, 4.0);
        assert!(r.any_active());
        // absent section keeps the all-off defaults
        let cfg = RunConfig::from_toml_str("[run]\ngpus = 4\n").unwrap();
        assert_eq!(cfg.serve.resilience, ResilienceConfig::default());
        // invalid values are rejected at validate time
        assert!(RunConfig::from_toml_str("[resilience]\nretry_max_attempts = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[resilience]\nretry_max_attempts = 99\n").is_err());
    }

    #[test]
    fn toml_priority_section() {
        let cfg = RunConfig::from_toml_str(
            "[priority]\nscheduling = true\ntokenizer = true\nbrownout = true\n\
             brownout_window_s = 0.5\nbrownout_output_cap = 4\n",
        )
        .unwrap();
        let p = &cfg.serve.priority;
        assert!(p.scheduling && p.tokenizer && p.brownout);
        assert!(p.any_active());
        assert_eq!(p.brownout_window_s, 0.5);
        assert_eq!(p.brownout_output_cap, 4);
        // untouched knobs keep their defaults
        assert_eq!(p.brownout_down_after, PriorityConfig::default().brownout_down_after);
        // absent section keeps the all-off defaults
        let cfg = RunConfig::from_toml_str("[run]\ngpus = 4\n").unwrap();
        assert_eq!(cfg.serve.priority, PriorityConfig::default());
        assert!(!cfg.serve.priority.any_active());
        // invalid values are rejected at validate time
        assert!(RunConfig::from_toml_str("[priority]\nbrownout_window_s = 0.0\n").is_err());
        assert!(RunConfig::from_toml_str("[priority]\nbrownout_output_cap = 0\n").is_err());
    }

    #[test]
    fn toml_fleet_section() {
        let cfg = RunConfig::from_toml_str(
            "[fleet]\nreplicas = 4\nrouter = \"least-loaded\"\nfailure_aware = true\n\
             hedge_delay_s = 0.5\nautoscale = true\nmax_cores_per_replica = 8\n",
        )
        .unwrap();
        let f = &cfg.serve.fleet;
        assert_eq!(f.replicas, 4);
        assert_eq!(f.router, RouterPolicy::LeastLoaded);
        assert!(f.failure_aware);
        assert_eq!(f.hedge_delay_s, 0.5);
        assert!(f.autoscale);
        assert_eq!(f.max_cores_per_replica, 8);
        assert!(f.enabled());
        // absent section keeps the single-replica default
        let cfg = RunConfig::from_toml_str("[run]\ngpus = 4\n").unwrap();
        assert_eq!(cfg.serve.fleet, FleetConfig::default());
        // invalid values are rejected
        assert!(RunConfig::from_toml_str("[fleet]\nrouter = \"random\"\n").is_err());
        assert!(RunConfig::from_toml_str("[fleet]\nreplicas = 0\n").is_err());
    }

    #[test]
    fn toml_fleet_pools_section() {
        let cfg = RunConfig::from_toml_str(
            "[fleet]\nreplicas = 4\n[fleet.pools]\nprefill = 1\ndecode = 3\n\
             transfer_gb_per_s = 50.0\ntransfer_max_attempts = 2\n",
        )
        .unwrap();
        let p = &cfg.serve.fleet.pools;
        assert!(p.enabled());
        assert_eq!((p.prefill, p.decode), (1, 3));
        assert_eq!(p.transfer_gb_per_s, 50.0);
        assert_eq!(p.transfer_max_attempts, 2);
        // untouched knobs keep their defaults
        assert_eq!(p.max_inflight_per_decode, 8);
        // absent subsection keeps pools off
        let cfg = RunConfig::from_toml_str("[fleet]\nreplicas = 4\n").unwrap();
        assert!(!cfg.serve.fleet.pools.enabled());
        // partition mismatch and pools-without-fleet are rejected
        assert!(RunConfig::from_toml_str(
            "[fleet]\nreplicas = 4\n[fleet.pools]\nprefill = 2\ndecode = 3\n"
        )
        .is_err());
        assert!(
            RunConfig::from_toml_str("[fleet.pools]\nprefill = 1\ndecode = 1\n").is_err()
        );
    }

    #[test]
    fn toml_scales_and_profile() {
        let cfg = RunConfig::from_toml_str(
            "[serve]\nprofile = true\n[scales]\ntokenize = 0.5\ncomm = 1.5\n",
        )
        .unwrap();
        assert!(cfg.serve.profile);
        assert_eq!(cfg.scales.tokenize, 0.5);
        assert_eq!(cfg.scales.launch, 1.0);
        assert_eq!(cfg.scales.comm, 1.5);
        assert_eq!(cfg.scales.compute, 1.0);
        // absent sections keep the exact-no-op defaults
        let cfg = RunConfig::from_toml_str("[run]\ngpus = 4\n").unwrap();
        assert!(!cfg.serve.profile);
        assert_eq!(cfg.scales, CostScales::default());
        // non-positive scales are rejected
        assert!(RunConfig::from_toml_str("[scales]\nlaunch = 0.0\n").is_err());
        assert!(RunConfig::from_toml_str("[scales]\ncompute = -1.0\n").is_err());
    }

    #[test]
    fn paper_levels() {
        assert_eq!(RunConfig::paper_core_levels(4), vec![5, 8, 16, 32]);
        assert_eq!(RunConfig::paper_core_levels(8), vec![9, 16, 32, 64]);
    }
}
