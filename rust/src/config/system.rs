//! System (machine) specifications — the paper's Table I plus knobs for
//! the host-side cost model that the simulator needs.
//!
//! GPU compute/bandwidth numbers are public datasheet values; the
//! host-side latency constants (kernel launch cost, context-switch cost,
//! timeslice) are taken from the literature the paper cites (launches are
//! "microseconds" that degrade "to milliseconds" under contention) and
//! are configurable.

/// Inter-GPU interconnect, which sets collective-communication bandwidth
/// (Table I: NVLink 4.0 at 900 GB/s vs PCIe 5.0 at 64 GB/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Interconnect {
    /// NVLink with the given per-GPU aggregate bandwidth (bytes/s).
    NvLink { bw_bytes_per_s: f64 },
    /// PCIe with the given per-link bandwidth (bytes/s).
    Pcie { bw_bytes_per_s: f64 },
}

impl Interconnect {
    pub fn bw_bytes_per_s(&self) -> f64 {
        match self {
            Interconnect::NvLink { bw_bytes_per_s } => *bw_bytes_per_s,
            Interconnect::Pcie { bw_bytes_per_s } => *bw_bytes_per_s,
        }
    }

    /// Per-hop latency for a collective step. NVLink is ~1–2 µs; PCIe,
    /// with driver involvement and no direct peer path, is ~5–10 µs.
    pub fn hop_latency_s(&self) -> f64 {
        match self {
            Interconnect::NvLink { .. } => 1.5e-6,
            Interconnect::Pcie { .. } => 7.0e-6,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Interconnect::NvLink { .. } => "NVLink",
            Interconnect::Pcie { .. } => "PCIe",
        }
    }
}

/// A CPU-GPU heterogeneous node (one row of Table I).
#[derive(Debug, Clone)]
pub struct SystemSpec {
    pub name: String,
    pub gpu_arch: String,
    pub cpu_model: String,
    /// Physical cores available on the node (SMT disabled, per §III).
    pub cpu_cores: usize,
    pub gpus_per_node: usize,
    pub interconnect: Interconnect,

    // --- GPU device model ---
    /// Peak dense BF16 throughput per GPU (FLOP/s).
    pub gpu_peak_flops: f64,
    /// HBM bandwidth per GPU (bytes/s).
    pub gpu_mem_bw: f64,
    /// Achievable fraction of peak in practice (MFU-style derate).
    pub gpu_efficiency: f64,

    // --- host-side cost model ---
    /// Single-core throughput scale relative to Xeon 8480CL (1.0).
    pub cpu_single_core_scale: f64,
    /// CPU time to issue one kernel launch, uncontended (seconds).
    /// Paper §II-A: launches are "microseconds" uncontended.
    pub kernel_launch_cpu_s: f64,
    /// OS context-switch cost (seconds) — direct cost per switch.
    pub context_switch_s: f64,
    /// Scheduler timeslice for CFS-like round-robin (seconds).
    pub timeslice_s: f64,
    /// Tokenizer throughput per core: seconds of CPU per input token on
    /// the serving stack's tokenize path.
    ///
    /// NOTE this is the *effective* per-token cost inside the vLLM V1
    /// API-server process (HF tokenizer + Python pre/post-processing +
    /// tensorization), not raw Rust-BPE throughput. Figure 5 of the
    /// paper shows tokenization ≈ 30–50% of TTFT while chunked prefill
    /// of the same prompt takes seconds on 4×H200 — back-solving gives
    /// ~40k tokens/s/core (≈25 µs/token). Our own Rust BPE encoder runs
    /// >20× faster (see `cpuslow calibrate`) — more still since the
    /// heap-merge fast path replaced the naive quadratic loop; rerun
    /// `cpuslow calibrate` after encoder changes before comparing
    /// simulated tokenization costs across versions. The gap is
    /// consistent with being Python-side; the simulator models the
    /// stack the paper measured.
    pub tokenize_s_per_token: f64,
}

impl SystemSpec {
    /// Table I row 1: DGX-class H100 node.
    pub fn h100() -> SystemSpec {
        SystemSpec {
            name: "H100".into(),
            gpu_arch: "Hopper (9.0)".into(),
            cpu_model: "Intel Xeon Platinum 8480CL".into(),
            cpu_cores: 64,
            gpus_per_node: 8,
            interconnect: Interconnect::NvLink {
                bw_bytes_per_s: 900e9,
            },
            gpu_peak_flops: 989e12, // H100 SXM BF16 dense
            gpu_mem_bw: 3.35e12,
            gpu_efficiency: 0.45,
            cpu_single_core_scale: 1.0,
            kernel_launch_cpu_s: 6.0e-6,
            context_switch_s: 3.0e-6,
            timeslice_s: 1.0e-3,
            tokenize_s_per_token: 15.0e-6,
        }
    }

    /// Table I row 2: H200 node (same host/interconnect, more HBM BW).
    pub fn h200() -> SystemSpec {
        SystemSpec {
            name: "H200".into(),
            gpu_mem_bw: 4.8e12,
            ..SystemSpec::h100()
        }
    }

    /// Table I row 3: RTX Pro 6000 Blackwell node — no NVLink, PCIe 5.0
    /// at 64 GB/s, dual Xeon 6737P host.
    pub fn blackwell() -> SystemSpec {
        SystemSpec {
            name: "RTX Pro 6000".into(),
            gpu_arch: "Blackwell (12.0)".into(),
            cpu_model: "Dual Intel Xeon 6737P".into(),
            cpu_cores: 64,
            gpus_per_node: 8,
            interconnect: Interconnect::Pcie {
                bw_bytes_per_s: 64e9,
            },
            gpu_peak_flops: 503e12, // RTX Pro 6000 dense BF16 (no sparsity)
            gpu_mem_bw: 1.79e12,
            gpu_efficiency: 0.40,
            cpu_single_core_scale: 1.05,
            kernel_launch_cpu_s: 6.0e-6,
            context_switch_s: 3.0e-6,
            timeslice_s: 1.0e-3,
            tokenize_s_per_token: 15.0e-6,
        }
    }

    /// All Table I systems, in paper order.
    pub fn table1() -> Vec<SystemSpec> {
        vec![Self::h100(), Self::h200(), Self::blackwell()]
    }

    pub fn by_name(name: &str) -> Option<SystemSpec> {
        match name.to_ascii_lowercase().replace([' ', '-', '_'], "").as_str() {
            "h100" => Some(Self::h100()),
            "h200" => Some(Self::h200()),
            "blackwell" | "rtxpro6000" | "rtxpro" => Some(Self::blackwell()),
            _ => None,
        }
    }

    /// Effective sustained FLOP/s (peak × derate).
    pub fn gpu_sustained_flops(&self) -> f64 {
        self.gpu_peak_flops * self.gpu_efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let systems = SystemSpec::table1();
        assert_eq!(systems.len(), 3);
        assert_eq!(systems[0].name, "H100");
        assert_eq!(systems[0].cpu_cores, 64);
        assert_eq!(systems[0].gpus_per_node, 8);
        assert!(matches!(
            systems[0].interconnect,
            Interconnect::NvLink { .. }
        ));
        assert!(matches!(
            systems[2].interconnect,
            Interconnect::Pcie { .. }
        ));
        assert_eq!(systems[2].gpu_arch, "Blackwell (12.0)");
    }

    #[test]
    fn h200_has_more_bandwidth_than_h100() {
        assert!(SystemSpec::h200().gpu_mem_bw > SystemSpec::h100().gpu_mem_bw);
    }

    #[test]
    fn lookup_by_name() {
        assert!(SystemSpec::by_name("H100").is_some());
        assert!(SystemSpec::by_name("h200").is_some());
        assert!(SystemSpec::by_name("RTX Pro 6000").is_some());
        assert!(SystemSpec::by_name("blackwell").is_some());
        assert!(SystemSpec::by_name("tpu").is_none());
    }

    #[test]
    fn interconnect_bandwidths() {
        assert_eq!(
            SystemSpec::h100().interconnect.bw_bytes_per_s(),
            900e9
        );
        assert_eq!(
            SystemSpec::blackwell().interconnect.bw_bytes_per_s(),
            64e9
        );
        assert!(SystemSpec::blackwell().interconnect.hop_latency_s()
            > SystemSpec::h100().interconnect.hop_latency_s());
    }

    #[test]
    fn host_constants_sane() {
        for s in SystemSpec::table1() {
            assert!(s.kernel_launch_cpu_s > 1e-7 && s.kernel_launch_cpu_s < 1e-4);
            assert!(s.context_switch_s > 1e-7 && s.context_switch_s < 1e-4);
            assert!(s.timeslice_s >= 1e-4);
            assert!(s.gpu_efficiency > 0.0 && s.gpu_efficiency <= 1.0);
        }
    }
}
