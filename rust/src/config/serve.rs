//! Serving-engine configuration (the knobs vLLM V1 exposes that matter
//! for the paper's experiments), plus the workload-scenario selection
//! block that run TOML files carry in their `workload` table.

use anyhow::{bail, Result};

/// Scenario-driven workload selection. Carried by
/// [`RunConfig`](crate::config::RunConfig) and filled from the
/// `workload` table of a run TOML file; consumed by `cpuslow serve`
/// and `cpuslow serve-sweep`. The scenario *name* resolves against the
/// catalog in `crate::workload::scenario` at use time — config stays a
/// lower layer and never imports the workload module.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Catalog scenario name; empty string = no scenario selected
    /// (callers fall back to their plain request stream).
    pub scenario: String,
    /// Override the scenario's default generation window (seconds).
    pub duration_s: Option<f64>,
    /// Multiplier applied to every class's offered arrival rate.
    pub rate_scale: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            scenario: String::new(),
            duration_s: None,
            rate_scale: 1.0,
        }
    }
}

impl WorkloadConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.rate_scale > 0.0 && self.rate_scale.is_finite()) {
            bail!("workload.rate_scale must be positive and finite");
        }
        if let Some(d) = self.duration_s {
            if !(d > 0.0 && d.is_finite()) {
                bail!("workload.duration_s must be positive and finite");
            }
        }
        Ok(())
    }
}

/// Hard cap on configurable retry attempts: with exponential backoff a
/// deeper retry chain only postpones the terminal outcome past any
/// realistic observation horizon, and an absurd setting (`u32::MAX`)
/// would turn every shed request into an unbounded arrival storm.
pub const MAX_RETRY_ATTEMPTS: u32 = 16;

/// Resilience knobs: admission control, load shedding, the deadline
/// watchdog, and client-side retry. The default turns every gate off so
/// existing runs stay byte-identical; scenarios opt in per catalog
/// entry (`Scenario::resilience`).
///
/// Each class's deadline is its TTFT SLO (installed by the scenario
/// drivers through `ServingSim::set_class_deadlines`); requests without
/// a per-class deadline fall back to [`ServeConfig::timeout_s`].
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceConfig {
    /// Queue-depth admission gate: shed arrivals while more than this
    /// many requests sit in the scheduler's waiting queue. 0 = off.
    pub admission_max_queue: usize,
    /// Estimated-TTFT shedding gate: shed an arrival whose projected
    /// first token (queue drain at the observed step time) would land
    /// past `factor ×` its class deadline. 0.0 = off.
    pub shed_slo_factor: f64,
    /// Deadline watchdog: abort in-flight requests older than `factor ×`
    /// their class deadline and reclaim their KV pages. 0.0 = off.
    pub watchdog_slo_factor: f64,
    /// Total delivery attempts per logical request (1 = no retry).
    /// Shed and aborted requests re-enter the arrival stream with
    /// exponential backoff; rejected requests never retry (a request
    /// that cannot fit in KV today cannot fit tomorrow either).
    pub retry_max_attempts: u32,
    /// Base backoff before the first retry (seconds); doubles per
    /// attempt with deterministic jitter in [0.5, 1.0).
    pub retry_base_s: f64,
    /// Ceiling on the un-jittered backoff (seconds).
    pub retry_cap_s: f64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            admission_max_queue: 0,
            shed_slo_factor: 0.0,
            watchdog_slo_factor: 0.0,
            retry_max_attempts: 1,
            retry_base_s: 0.5,
            retry_cap_s: 8.0,
        }
    }
}

impl ResilienceConfig {
    /// Is any gate (shedding, watchdog, or retry) active?
    pub fn any_active(&self) -> bool {
        self.admission_max_queue > 0
            || self.shed_slo_factor > 0.0
            || self.watchdog_slo_factor > 0.0
            || self.retry_max_attempts > 1
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.shed_slo_factor >= 0.0 && self.shed_slo_factor.is_finite()) {
            bail!("resilience.shed_slo_factor must be ≥ 0 and finite");
        }
        if !(self.watchdog_slo_factor >= 0.0 && self.watchdog_slo_factor.is_finite()) {
            bail!("resilience.watchdog_slo_factor must be ≥ 0 and finite");
        }
        if self.retry_max_attempts == 0 {
            bail!("resilience.retry_max_attempts must be ≥ 1 (1 = no retry)");
        }
        if self.retry_max_attempts > MAX_RETRY_ATTEMPTS {
            bail!("resilience.retry_max_attempts must be ≤ {MAX_RETRY_ATTEMPTS}");
        }
        if !(self.retry_base_s > 0.0 && self.retry_base_s.is_finite()) {
            bail!("resilience.retry_base_s must be positive and finite");
        }
        if !(self.retry_cap_s > 0.0 && self.retry_cap_s.is_finite()) {
            bail!("resilience.retry_cap_s must be positive and finite");
        }
        Ok(())
    }
}

/// Priority / graceful-degradation knobs: class-priority scheduling
/// with KV-pressure recompute preemption, a priority tokenizer job
/// queue, and the brownout degradation ladder. Every gate defaults off
/// so existing runs stay byte-identical; scenarios opt in per catalog
/// entry (`Scenario::priority`).
///
/// Class priorities come from the workload (`ClassSpec::priority`,
/// installed through `ServingSim::set_class_priorities`); higher values
/// win. Requests without a class priority run at 0.
#[derive(Debug, Clone, PartialEq)]
pub struct PriorityConfig {
    /// Priority-aware admission — waiting requests are admitted by
    /// (priority desc, arrival seq asc) instead of pure FCFS — plus
    /// KV-pressure preemption: when a higher-priority candidate cannot
    /// grow its KV reservation, the lowest-priority running request is
    /// evicted (recompute preemption) and re-queued. Off = FCFS.
    pub scheduling: bool,
    /// Priority job queue in the tokenizer pool: workers pop the
    /// highest-priority queued tokenize job (FIFO within a priority)
    /// so chat jobs jump batch backlog. Off = pure FIFO.
    pub tokenizer: bool,
    /// Brownout degradation ladder: a per-probe-window state machine
    /// (Normal → CapBatchOutput → ShedBatchAtAdmission → PauseBatch)
    /// driven by the estimated-TTFT headroom of the highest-priority
    /// class; each level degrades lower-priority traffic harder.
    pub brownout: bool,
    /// Brownout probe window (seconds).
    pub brownout_window_s: f64,
    /// Consecutive bad windows before stepping one level down the
    /// ladder (hysteresis, like the fleet health machine).
    pub brownout_down_after: u32,
    /// Consecutive good windows before stepping one level back up.
    pub brownout_up_after: u32,
    /// A window is "bad" when the projected first-token latency of a
    /// fresh top-priority arrival (queue drain at the observed step
    /// time) exceeds `factor ×` the top-priority class deadline.
    pub brownout_slo_factor: f64,
    /// Output-token cap applied to lower-priority requests admitted at
    /// CapBatchOutput or deeper.
    pub brownout_output_cap: u64,
}

impl Default for PriorityConfig {
    fn default() -> Self {
        Self {
            scheduling: false,
            tokenizer: false,
            brownout: false,
            brownout_window_s: 0.25,
            brownout_down_after: 2,
            brownout_up_after: 2,
            brownout_slo_factor: 0.5,
            brownout_output_cap: 8,
        }
    }
}

impl PriorityConfig {
    /// Is any priority gate (scheduling, tokenizer queue, brownout) on?
    pub fn any_active(&self) -> bool {
        self.scheduling || self.tokenizer || self.brownout
    }

    /// Arm every gate (the `--priority` CLI override).
    pub fn armed() -> Self {
        Self {
            scheduling: true,
            tokenizer: true,
            brownout: true,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.brownout_window_s > 0.0 && self.brownout_window_s.is_finite()) {
            bail!("priority.brownout_window_s must be positive and finite");
        }
        if self.brownout_down_after == 0 || self.brownout_up_after == 0 {
            bail!("priority.brownout_down_after and brownout_up_after must be ≥ 1");
        }
        if !(self.brownout_slo_factor > 0.0 && self.brownout_slo_factor.is_finite()) {
            bail!("priority.brownout_slo_factor must be positive and finite");
        }
        if self.brownout_output_cap == 0 {
            bail!("priority.brownout_output_cap must be ≥ 1");
        }
        Ok(())
    }
}

/// Fleet router policy: how the router picks a replica for each
/// arrival. Every policy is a pure function of (request identity,
/// router state at the decision window) — never completion order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// Rotate through eligible replicas in index order.
    #[default]
    RoundRobin,
    /// Pick the eligible replica with the fewest outstanding prompt
    /// tokens (router-side count; ties break to the lowest index).
    LeastLoaded,
    /// Rendezvous-hash the prompt's content seed over the eligible
    /// replicas, so repeated prompts land on the replica that holds
    /// their warm prefix-cache blocks.
    PrefixAffinity,
}

impl RouterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    pub fn by_name(name: &str) -> Option<RouterPolicy> {
        match name {
            "round-robin" => Some(RouterPolicy::RoundRobin),
            "least-loaded" => Some(RouterPolicy::LeastLoaded),
            "prefix-affinity" => Some(RouterPolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn all() -> [RouterPolicy; 3] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ]
    }
}

/// Disaggregated prefill/decode pool knobs (the Mooncake/DistServe
/// shape). Off by default (`prefill = decode = 0`), so the colocated
/// fleet path stays byte-identical. When enabled, replicas
/// `[0, prefill)` form the prefill pool and `[prefill, prefill+decode)`
/// the decode pool, and every request's KV state is handed off between
/// them as an explicit copy task on the shared CPU substrate — where it
/// contends with tokenization and can stall, fail, or back up.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolConfig {
    /// Replicas in the prefill pool. 0 = disaggregation off.
    pub prefill: usize,
    /// Replicas in the decode pool. 0 = disaggregation off.
    /// When both are nonzero they must sum to `fleet.replicas`.
    pub decode: usize,
    /// KV handoff bandwidth (GB/s) for the prefill→decode copy; the
    /// per-transfer cost is `transfer_base_s + kv_bytes / bandwidth`.
    pub transfer_gb_per_s: f64,
    /// Fixed per-transfer setup cost (seconds): connection + descriptor
    /// exchange before bytes move.
    pub transfer_base_s: f64,
    /// Total handoff attempts per request (1 = no transfer retry).
    /// A transfer that exhausts its budget falls back to re-prefilling
    /// in the decode pool.
    pub transfer_max_attempts: u32,
    /// Backpressure gate: defer prefill dispatch while the decode pool
    /// holds at least this many in-flight requests plus active
    /// transfers per decode replica.
    pub max_inflight_per_decode: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            prefill: 0,
            decode: 0,
            transfer_gb_per_s: 25.0,
            transfer_base_s: 0.000_5,
            transfer_max_attempts: 3,
            max_inflight_per_decode: 8,
        }
    }
}

impl PoolConfig {
    /// Is the disaggregated-pool layer on (both pools populated)?
    pub fn enabled(&self) -> bool {
        self.prefill > 0 && self.decode > 0
    }

    /// Parse the `--pools prefill=N,decode=M` CLI syntax.
    pub fn parse_cli(spec: &str) -> Result<(usize, usize)> {
        let (mut prefill, mut decode) = (None, None);
        for part in spec.split(',').filter(|s| !s.is_empty()) {
            let Some((key, value)) = part.split_once('=') else {
                bail!("--pools expects prefill=N,decode=M, got '{part}'");
            };
            let n: usize = value
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--pools: bad count '{value}'"))?;
            match key.trim() {
                "prefill" => prefill = Some(n),
                "decode" => decode = Some(n),
                other => bail!("--pools: unknown pool '{other}' (prefill/decode)"),
            }
        }
        match (prefill, decode) {
            (Some(p), Some(d)) => Ok((p, d)),
            _ => bail!("--pools must set both prefill= and decode="),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if (self.prefill == 0) != (self.decode == 0) {
            bail!("fleet.pools: prefill and decode must both be 0 (off) or both ≥ 1");
        }
        if !(self.transfer_gb_per_s > 0.0 && self.transfer_gb_per_s.is_finite()) {
            bail!("fleet.pools.transfer_gb_per_s must be positive and finite");
        }
        if !(self.transfer_base_s >= 0.0 && self.transfer_base_s.is_finite()) {
            bail!("fleet.pools.transfer_base_s must be ≥ 0 and finite");
        }
        if self.transfer_max_attempts == 0 {
            bail!("fleet.pools.transfer_max_attempts must be ≥ 1 (1 = no retry)");
        }
        if self.transfer_max_attempts > MAX_RETRY_ATTEMPTS {
            bail!("fleet.pools.transfer_max_attempts must be ≤ {MAX_RETRY_ATTEMPTS}");
        }
        if self.max_inflight_per_decode == 0 {
            bail!("fleet.pools.max_inflight_per_decode must be ≥ 1");
        }
        Ok(())
    }
}

/// Replicated-serving (fleet) knobs: replica count, router policy,
/// health probing, failover, hedging, and the reactive core autoscaler.
/// The default (`replicas = 1`) disables the whole layer, so existing
/// single-engine runs stay byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Data-parallel serving replicas behind the router. 1 = no fleet
    /// (plain `ServingSim`); each replica gets its own engine, GPU set,
    /// and tokenizer pool on the shared CPU substrate.
    pub replicas: usize,
    /// Replica-selection policy for new arrivals.
    pub router: RouterPolicy,
    /// Route around unhealthy replicas and re-dispatch requests that
    /// failed on them (their failures count as retries on the logical
    /// request). Off = the router keeps dispatching blindly.
    pub failure_aware: bool,
    /// Hedge delay (seconds): a request with no terminal outcome this
    /// long after dispatch is duplicated to a second replica; first
    /// completion wins and the loser is cancelled. 0 = hedging off.
    pub hedge_delay_s: f64,
    /// Max dispatch attempts per logical request across replicas
    /// (initial dispatch + failovers).
    pub failover_max_attempts: u32,
    /// Health-probe window (seconds): per window the router scores each
    /// replica's step progress, GPU idle share, and shed count.
    pub probe_interval_s: f64,
    /// A probe window is "bad" if the replica's windowed GPU idle share
    /// is at or above this while work is in flight.
    pub probe_idle_bad_share: f64,
    /// ... or if it shed at least this many requests in the window.
    pub probe_shed_bad: u32,
    /// Consecutive bad windows before a Degraded replica goes Down.
    pub down_after: u32,
    /// Consecutive good windows before a Down replica begins recovery.
    pub recover_after: u32,
    /// Recovery ramp length (windows): a recovering replica admits a
    /// deterministically-hashed fraction of arrivals that rises to full
    /// over this many windows (graceful drain in reverse).
    pub drain_ramp_windows: u32,
    /// Reactive core autoscaler: grow/shrink each replica's core
    /// allocation from its windowed GPU idle share.
    pub autoscale: bool,
    /// Autoscaler floor (cores per replica).
    pub min_cores_per_replica: usize,
    /// Autoscaler ceiling (cores per replica); 0 = the run's
    /// `cpu_cores` (no headroom beyond the static allocation).
    pub max_cores_per_replica: usize,
    /// Idle-share band: below `lo` the replica is CPU-rich (revoke a
    /// core), above `hi` it is CPU-starved (grant one).
    pub autoscale_idle_lo: f64,
    pub autoscale_idle_hi: f64,
    /// Autoscaler cadence: act every this many probe windows.
    pub autoscale_every: u32,
    /// Disaggregated prefill/decode pools with an explicit KV handoff.
    /// Defaults to off (colocated fleet).
    pub pools: PoolConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            failure_aware: false,
            hedge_delay_s: 0.0,
            failover_max_attempts: 4,
            probe_interval_s: 0.25,
            probe_idle_bad_share: 0.95,
            probe_shed_bad: 3,
            down_after: 2,
            recover_after: 4,
            drain_ramp_windows: 4,
            autoscale: false,
            min_cores_per_replica: 2,
            max_cores_per_replica: 0,
            autoscale_idle_lo: 0.15,
            autoscale_idle_hi: 0.60,
            autoscale_every: 2,
            pools: PoolConfig::default(),
        }
    }
}

impl FleetConfig {
    /// Is the fleet layer on (more than one replica)?
    pub fn enabled(&self) -> bool {
        self.replicas > 1
    }

    pub fn validate(&self) -> Result<()> {
        if self.replicas == 0 {
            bail!("fleet.replicas must be ≥ 1");
        }
        if self.replicas > 64 {
            bail!("fleet.replicas must be ≤ 64");
        }
        if !(self.hedge_delay_s >= 0.0 && self.hedge_delay_s.is_finite()) {
            bail!("fleet.hedge_delay_s must be ≥ 0 and finite");
        }
        if self.failover_max_attempts == 0 {
            bail!("fleet.failover_max_attempts must be ≥ 1");
        }
        if !(self.probe_interval_s > 0.0 && self.probe_interval_s.is_finite()) {
            bail!("fleet.probe_interval_s must be positive and finite");
        }
        if !(0.0..=1.0).contains(&self.probe_idle_bad_share) {
            bail!("fleet.probe_idle_bad_share must be in [0,1]");
        }
        if self.down_after == 0 || self.recover_after == 0 {
            bail!("fleet.down_after and fleet.recover_after must be ≥ 1");
        }
        if self.drain_ramp_windows == 0 {
            bail!("fleet.drain_ramp_windows must be ≥ 1");
        }
        if self.min_cores_per_replica == 0 {
            bail!("fleet.min_cores_per_replica must be ≥ 1");
        }
        if self.max_cores_per_replica != 0
            && self.max_cores_per_replica < self.min_cores_per_replica
        {
            bail!("fleet.max_cores_per_replica must be 0 (auto) or ≥ min_cores_per_replica");
        }
        if !(0.0..=1.0).contains(&self.autoscale_idle_lo)
            || !(0.0..=1.0).contains(&self.autoscale_idle_hi)
            || self.autoscale_idle_lo >= self.autoscale_idle_hi
        {
            bail!("fleet.autoscale_idle band must satisfy 0 ≤ lo < hi ≤ 1");
        }
        if self.autoscale_every == 0 {
            bail!("fleet.autoscale_every must be ≥ 1");
        }
        self.pools.validate()?;
        if self.pools.enabled() {
            if !self.enabled() {
                bail!("fleet.pools requires fleet.replicas > 1");
            }
            if self.pools.prefill + self.pools.decode != self.replicas {
                bail!(
                    "fleet.pools: prefill ({}) + decode ({}) must equal fleet.replicas ({})",
                    self.pools.prefill,
                    self.pools.decode,
                    self.replicas
                );
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max requests resident in a decode batch (continuous batching cap).
    pub max_batch_size: usize,
    /// Chunked-prefill token budget per engine step (vLLM's
    /// `max_num_batched_tokens`). Prefill longer than this is split into
    /// chunks interleaved with decode — this is what makes prefill time
    /// near-linear in sequence length (§IV-A).
    pub prefill_chunk_tokens: usize,
    /// KV-cache page size in tokens.
    pub kv_page_tokens: usize,
    /// Total KV pages per GPU (sized from HBM capacity in practice; fixed
    /// here so experiments are deterministic).
    pub kv_pages_per_gpu: usize,
    /// Enable prefix caching (vLLM default on).
    pub prefix_caching: bool,
    /// Enable CUDA-Graph-style launch amortization for decode steps
    /// ("full-and-piecewise" in vLLM v0.11): captured segments cost one
    /// launch, dynamic segments still launch per-kernel.
    pub cuda_graphs: bool,
    /// Fraction of decode kernels that remain dynamic (not capturable) —
    /// EOS checks, sampling, stop conditions (§II-A ③).
    pub graph_dynamic_fraction: f64,
    /// Tokenizer worker threads in the API-server process. HF tokenizers
    /// spawns a Rayon pool sized to the visible cores
    /// (TOKENIZERS_PARALLELISM=true, §II-A ①); 0 = auto (one thread per
    /// allocated core), matching that default.
    pub tokenizer_threads: usize,
    /// Request timeout (seconds). Paper uses 200 s (§IV-B).
    pub timeout_s: f64,
    /// Max output tokens generated per request.
    pub max_output_tokens: usize,
    /// CFS weight for the latency-critical control-plane tasks
    /// (EngineCore + GPU workers). 1 = default OS behavior (the paper's
    /// measured setup: "the default OS scheduler treats all processes
    /// equally", §VI-A); >1 models the nice/cgroup prioritization the
    /// paper proposes evaluating as future work.
    pub control_plane_weight: u32,
    /// Resilience layer: admission control, shedding, watchdog, retry.
    /// All gates default off (legacy behavior).
    pub resilience: ResilienceConfig,
    /// Fleet layer: replicated serving behind a deterministic router.
    /// Defaults to one replica (layer off).
    pub fleet: FleetConfig,
    /// Priority layer: class-priority scheduling + preemption,
    /// priority tokenize queue, and the brownout ladder. All gates
    /// default off (legacy FCFS behavior).
    pub priority: PriorityConfig,
    /// Arm the always-on attribution profiler (`profile::Profiler`):
    /// ring-buffer span tracing plus per-request phase timelines.
    /// Observation-only — outcomes are byte-identical either way (the
    /// differential tests pin this) — but reports then carry a
    /// `ProfileReport`. Default off.
    pub profile: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch_size: 256,
            prefill_chunk_tokens: 2048, // vLLM V1 default max_num_batched_tokens
            kv_page_tokens: 16,
            kv_pages_per_gpu: 32_768,
            prefix_caching: true,
            cuda_graphs: true,
            graph_dynamic_fraction: 0.25,
            tokenizer_threads: 0, // auto: one per allocated core
            timeout_s: 200.0,
            max_output_tokens: 32,
            control_plane_weight: 1,
            resilience: ResilienceConfig::default(),
            fleet: FleetConfig::default(),
            priority: PriorityConfig::default(),
            profile: false,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_batch_size == 0 {
            bail!("max_batch_size must be ≥ 1");
        }
        if self.prefill_chunk_tokens == 0 {
            bail!("prefill_chunk_tokens must be ≥ 1");
        }
        if self.kv_page_tokens == 0 || self.kv_pages_per_gpu == 0 {
            bail!("KV cache must have nonzero pages");
        }
        if !(0.0..=1.0).contains(&self.graph_dynamic_fraction) {
            bail!("graph_dynamic_fraction must be in [0,1]");
        }
        if self.timeout_s <= 0.0 {
            bail!("timeout must be positive");
        }
        if self.control_plane_weight == 0 {
            bail!("control_plane_weight must be ≥ 1");
        }
        self.resilience.validate()?;
        self.fleet.validate()?;
        self.priority.validate()?;
        Ok(())
    }

    /// KV capacity in tokens per GPU.
    pub fn kv_capacity_tokens(&self) -> usize {
        self.kv_page_tokens * self.kv_pages_per_gpu
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_fraction() {
        let cfg = ServeConfig {
            graph_dynamic_fraction: 1.5,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_zero_batch() {
        let cfg = ServeConfig {
            max_batch_size: 0,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn kv_capacity() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.kv_capacity_tokens(), 16 * 32_768);
    }

    #[test]
    fn resilience_defaults_off_and_valid() {
        let r = ResilienceConfig::default();
        r.validate().unwrap();
        assert!(!r.any_active());
    }

    #[test]
    fn resilience_rejects_bad_values() {
        let bad = ResilienceConfig {
            shed_slo_factor: -1.0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ResilienceConfig {
            watchdog_slo_factor: f64::NAN,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ResilienceConfig {
            retry_max_attempts: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ResilienceConfig {
            retry_max_attempts: MAX_RETRY_ATTEMPTS + 1,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ResilienceConfig {
            retry_base_s: 0.0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ResilienceConfig {
            retry_cap_s: f64::INFINITY,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn serve_validate_covers_resilience() {
        let cfg = ServeConfig {
            resilience: ResilienceConfig {
                retry_max_attempts: 0,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn priority_defaults_off_and_valid() {
        let p = PriorityConfig::default();
        p.validate().unwrap();
        assert!(!p.any_active());
        let armed = PriorityConfig::armed();
        armed.validate().unwrap();
        assert!(armed.scheduling && armed.tokenizer && armed.brownout);
    }

    #[test]
    fn priority_rejects_bad_values() {
        for p in [
            PriorityConfig { brownout_window_s: 0.0, ..Default::default() },
            PriorityConfig { brownout_window_s: f64::NAN, ..Default::default() },
            PriorityConfig { brownout_down_after: 0, ..Default::default() },
            PriorityConfig { brownout_up_after: 0, ..Default::default() },
            PriorityConfig { brownout_slo_factor: 0.0, ..Default::default() },
            PriorityConfig { brownout_output_cap: 0, ..Default::default() },
        ] {
            assert!(p.validate().is_err(), "{p:?} should be rejected");
        }
    }

    #[test]
    fn serve_validate_covers_priority() {
        let cfg = ServeConfig {
            priority: PriorityConfig { brownout_output_cap: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn fleet_defaults_off_and_valid() {
        let f = FleetConfig::default();
        f.validate().unwrap();
        assert!(!f.enabled());
        assert_eq!(f.router, RouterPolicy::RoundRobin);
    }

    #[test]
    fn fleet_rejects_bad_values() {
        for f in [
            FleetConfig { replicas: 0, ..Default::default() },
            FleetConfig { replicas: 65, ..Default::default() },
            FleetConfig { hedge_delay_s: -1.0, ..Default::default() },
            FleetConfig { failover_max_attempts: 0, ..Default::default() },
            FleetConfig { probe_interval_s: 0.0, ..Default::default() },
            FleetConfig { probe_idle_bad_share: 1.5, ..Default::default() },
            FleetConfig { down_after: 0, ..Default::default() },
            FleetConfig { recover_after: 0, ..Default::default() },
            FleetConfig { drain_ramp_windows: 0, ..Default::default() },
            FleetConfig { min_cores_per_replica: 0, ..Default::default() },
            FleetConfig {
                min_cores_per_replica: 8,
                max_cores_per_replica: 4,
                ..Default::default()
            },
            FleetConfig {
                autoscale_idle_lo: 0.7,
                autoscale_idle_hi: 0.6,
                ..Default::default()
            },
            FleetConfig { autoscale_every: 0, ..Default::default() },
        ] {
            assert!(f.validate().is_err(), "{f:?} should be rejected");
        }
    }

    #[test]
    fn pools_default_off_and_valid() {
        let p = PoolConfig::default();
        p.validate().unwrap();
        assert!(!p.enabled());
        // A fleet with pools disabled validates regardless of replicas.
        FleetConfig { replicas: 4, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn pools_partition_must_match_replicas() {
        let ok = FleetConfig {
            replicas: 4,
            pools: PoolConfig { prefill: 1, decode: 3, ..Default::default() },
            ..Default::default()
        };
        ok.validate().unwrap();
        assert!(ok.pools.enabled());
        for f in [
            // partition doesn't sum to replicas
            FleetConfig {
                replicas: 4,
                pools: PoolConfig { prefill: 2, decode: 3, ..Default::default() },
                ..Default::default()
            },
            // pools on a single-replica fleet
            FleetConfig {
                replicas: 1,
                pools: PoolConfig { prefill: 1, decode: 1, ..Default::default() },
                ..Default::default()
            },
            // half-enabled
            FleetConfig {
                replicas: 4,
                pools: PoolConfig { prefill: 4, decode: 0, ..Default::default() },
                ..Default::default()
            },
        ] {
            assert!(f.validate().is_err(), "{f:?} should be rejected");
        }
    }

    #[test]
    fn pools_reject_bad_knobs() {
        for p in [
            PoolConfig { transfer_gb_per_s: 0.0, ..Default::default() },
            PoolConfig { transfer_base_s: -1.0, ..Default::default() },
            PoolConfig { transfer_max_attempts: 0, ..Default::default() },
            PoolConfig {
                transfer_max_attempts: MAX_RETRY_ATTEMPTS + 1,
                ..Default::default()
            },
            PoolConfig { max_inflight_per_decode: 0, ..Default::default() },
        ] {
            assert!(p.validate().is_err(), "{p:?} should be rejected");
        }
    }

    #[test]
    fn pools_cli_syntax() {
        assert_eq!(PoolConfig::parse_cli("prefill=2,decode=6").unwrap(), (2, 6));
        assert_eq!(PoolConfig::parse_cli("decode=1,prefill=3").unwrap(), (3, 1));
        assert!(PoolConfig::parse_cli("prefill=2").is_err());
        assert!(PoolConfig::parse_cli("prefill=x,decode=1").is_err());
        assert!(PoolConfig::parse_cli("warm=1,decode=1").is_err());
        assert!(PoolConfig::parse_cli("").is_err());
    }

    #[test]
    fn router_policy_names_roundtrip() {
        for p in RouterPolicy::all() {
            assert_eq!(RouterPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(RouterPolicy::by_name("random"), None);
    }

    #[test]
    fn serve_validate_covers_fleet() {
        let cfg = ServeConfig {
            fleet: FleetConfig { replicas: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn workload_defaults_valid() {
        let w = WorkloadConfig::default();
        w.validate().unwrap();
        assert!(w.scenario.is_empty());
        assert_eq!(w.rate_scale, 1.0);
    }

    #[test]
    fn workload_rejects_bad_values() {
        let w = WorkloadConfig {
            rate_scale: 0.0,
            ..Default::default()
        };
        assert!(w.validate().is_err());
        let w = WorkloadConfig {
            duration_s: Some(-1.0),
            ..Default::default()
        };
        assert!(w.validate().is_err());
    }
}
