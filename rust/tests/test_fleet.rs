//! Fleet-layer acceptance tests: replicated serving behind the
//! deterministic router — failover beats blind routing and fat single
//! engines under a replica-scoped fault, sweeps stay byte-identical
//! across `--jobs`, failover retries replay from a dumped trace, the
//! diurnal autoscaler's grant log is a pure function of its windows,
//! and hedged requests never break exactly-one-outcome-per-request.

use cpuslow::config::{FleetConfig, ModelSpec, RouterPolicy, RunConfig, ServeConfig, SystemSpec};
use cpuslow::engine::{FaultSpec, Outcome, ReqClass, StreamArrival};
use cpuslow::experiments::serve_sweep;
use cpuslow::fleet::FleetSim;
use cpuslow::sweep::{seeded_cells, Sweep};
use cpuslow::workload::scenario::{run_trace, Scenario, ScenarioReport, Trace};

fn cfg(n_gpus: usize, cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), n_gpus, cores)
}

/// Acceptance criterion: on the replica-failure workload, a
/// failure-aware fleet must strictly beat (a) the same fleet routing
/// blindly and (b) a single replica holding the fleet's entire core
/// budget — the fault stalls 1/4 of a fleet but 100% of a single
/// engine, and only failure-aware routing moves work off the corpse.
#[test]
fn failure_aware_fleet_beats_blind_fleet_and_fat_single() {
    let scenario = Scenario::by_name("replica-failure-with-failover").unwrap();
    let mut trace = scenario.generate(5);
    assert!(trace.fleet.is_some(), "scenario must carry its fleet topology");
    // Tighten the SLO so the six-second stall cannot hide inside it.
    trace.classes[0].slo_ttft_s = 3.0;
    let cores = 8;

    let aware = run_trace(cfg(2, cores), &trace);

    let mut blind_trace = trace.clone();
    blind_trace.fleet = Some(FleetConfig {
        replicas: 4,
        router: RouterPolicy::RoundRobin,
        failure_aware: false,
        ..FleetConfig::default()
    });
    let blind = run_trace(cfg(2, cores), &blind_trace);

    let mut single_trace = trace.clone();
    single_trace.fleet = None;
    let single = run_trace(cfg(2, 4 * cores), &single_trace);

    assert_eq!(aware.replicas, 4);
    assert_eq!(blind.replicas, 4);
    assert_eq!(single.replicas, 1);
    assert_eq!(aware.issued, blind.issued);
    assert_eq!(aware.issued, single.issued);
    assert!(aware.issued > 0);

    let bad = |r: &ScenarioReport| r.timeouts + r.shed;
    assert!(
        bad(&single) > 0,
        "the fault must hurt the single engine (timeouts+shed {})",
        bad(&single)
    );
    assert!(
        bad(&aware) < bad(&blind),
        "failure-aware ({}) must beat blind round-robin ({})",
        bad(&aware),
        bad(&blind)
    );
    assert!(
        bad(&aware) < bad(&single),
        "failure-aware fleet ({}) must beat a 4x-core single replica ({})",
        bad(&aware),
        bad(&single)
    );
}

/// Failover retries are keyed by fleet origin id, so a dumped trace
/// replays the faulted fleet run exactly — same outcomes, same retry
/// ledger, same step count.
#[test]
fn failover_retries_reproduce_from_dumped_trace() {
    let scenario = Scenario::by_name("replica-failure-with-failover").unwrap();
    let trace = scenario.generate(2);
    let a = run_trace(cfg(2, 8), &trace);
    assert_eq!(a.replicas, 4);
    assert!(a.issued > 0);
    assert!(
        a.retries > 0,
        "the downed replica must force at least one failover re-dispatch"
    );

    let dump = trace.to_json().to_string_pretty();
    let parsed = cpuslow::util::json::parse(&dump).unwrap();
    let replay = Trace::from_json(&parsed).unwrap();
    assert_eq!(replay, trace, "fleet topology survives the dump");

    let b = run_trace(cfg(2, 8), &replay);
    assert_eq!(a.issued, b.issued);
    assert_eq!(a.timeouts, b.timeouts);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.aborted, b.aborted);
    assert_eq!(a.retries, b.retries);
    assert_eq!(a.ttft_p50_s, b.ttft_p50_s);
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s);
    assert_eq!(a.steps_completed, b.steps_completed);
}

fn fleet_sweep_output(jobs: usize) -> String {
    let scenarios = vec![
        Scenario::by_name("replica-failure-with-failover").unwrap().with_duration(6.0),
    ];
    let specs = serve_sweep::grid(
        &scenarios,
        &SystemSpec::h100(),
        &ModelSpec::llama31_8b(),
        &ServeConfig::default(),
        &[2],
        Some(&[6]),
        &[1, 4],
        &[RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded],
    );
    let cells = seeded_cells(0, specs);
    let results = Sweep::new("fleet-test", jobs)
        .quiet(true)
        .run(cells, serve_sweep::run_cell);
    let table = serve_sweep::render_cells("fleet determinism", &results).render();
    let json = serve_sweep::cells_to_json(&results).to_string_pretty();
    table + &json
}

/// Acceptance criterion: the fleet sweep — router decisions, health
/// probes, failover, the $/SLO-met cost column — is byte-identical
/// across `--jobs` values, because every router decision keys off
/// `(seed, origin id, window)` and never off worker schedule.
#[test]
fn fleet_sweep_jobs_byte_identical() {
    let serial = fleet_sweep_output(1);
    let parallel = fleet_sweep_output(3);
    assert!(serial.contains("router"), "sweep table carries the router column");
    assert!(serial.contains("$/SLO-met"), "sweep table carries the cost column");
    assert_eq!(serial, parallel);
}

/// The diurnal autoscaler converges reproducibly: its grant log is a
/// pure function of (window, stats), stays inside the configured core
/// band, and actually moves when the offered load swings.
#[test]
fn diurnal_autoscaler_grant_log_is_deterministic_and_bounded() {
    let scenario = Scenario::by_name("diurnal").unwrap().with_duration(16.0);
    let fleet = scenario.fleet.clone().expect("diurnal ships a fleet config");
    assert!(fleet.autoscale);
    let trace = scenario.generate(4);
    assert!(!trace.requests.is_empty());

    let run = || {
        let mut config = cfg(2, 4);
        config.serve.fleet = fleet.clone();
        let mut sim = FleetSim::new(config);
        sim.set_class_deadlines(&[20.0]);
        sim.set_run_seed(trace.seed);
        let arrivals: Vec<StreamArrival> = trace
            .requests
            .iter()
            .map(|r| StreamArrival {
                at_ns: r.at_ns,
                class: ReqClass::Normal,
                prompt_tokens: r.prompt_tokens,
                max_new_tokens: r.output_tokens,
                content_seed: r.content_seed,
                tag: r.class_idx as u32,
            })
            .collect();
        let mut outcomes = 0u64;
        sim.run_streaming(arrivals.into_iter(), 4.0, |_o| outcomes += 1);
        let wall_ns = sim.sim.now_ns();
        (sim.grant_log(), outcomes, sim.core_seconds(wall_ns))
    };

    let (log_a, n_a, core_s_a) = run();
    let (log_b, n_b, core_s_b) = run();
    assert_eq!(log_a, log_b, "grant decisions must be window-pure");
    assert_eq!(n_a, n_b);
    assert!(n_a > 0);
    assert!(!log_a.is_empty(), "the diurnal swing must move the autoscaler");
    for e in &log_a {
        assert!(
            e.cores >= fleet.min_cores_per_replica && e.cores <= fleet.max_cores_per_replica,
            "grant {e:?} outside [{}, {}]",
            fleet.min_cores_per_replica,
            fleet.max_cores_per_replica
        );
    }
    assert!(core_s_a > 0.0);
    assert!((core_s_a - core_s_b).abs() < 1e-9, "cost integral must replay");
}

/// Hedging preserves the exactly-one-terminal-outcome contract: with a
/// stalled replica forcing hedges (and the health prober racing it with
/// evictions), every logical request still reports exactly once, under
/// its fleet origin id, and the whole run replays byte-identically.
#[test]
fn hedged_requests_still_emit_exactly_one_outcome_each() {
    let n: u64 = 12;
    let run = || -> Vec<Outcome> {
        let mut config = cfg(2, 6);
        config.serve.fleet.replicas = 2;
        config.serve.fleet.failure_aware = true;
        config.serve.fleet.hedge_delay_s = 0.5;
        let mut sim = FleetSim::new(config);
        sim.set_class_deadlines(&[30.0]);
        sim.install_faults(&[FaultSpec::CoreLoss {
            start_s: 0.5,
            end_s: 4.0,
            cores: 6,
            replica: Some(0),
        }]);
        let arrivals = (0..n).map(|i| StreamArrival {
            at_ns: i * 250_000_000,
            class: ReqClass::Normal,
            prompt_tokens: 1_500,
            max_new_tokens: 16,
            content_seed: i,
            tag: 0,
        });
        let mut out = Vec::new();
        sim.run_streaming(arrivals, 30.0, |o| out.push(o));
        out
    };
    let a = run();
    assert_eq!(a.len() as u64, n, "exactly one terminal outcome per request");
    let mut origins: Vec<u64> = a.iter().map(|o| o.origin).collect();
    origins.sort_unstable();
    origins.dedup();
    assert_eq!(origins.len() as u64, n, "fleet origin ids are unique");
    let extra_deliveries: u32 = a.iter().map(|o| o.retries).sum();
    assert!(
        extra_deliveries > 0,
        "the stalled replica must force at least one hedge or failover"
    );
    let b = run();
    assert_eq!(a, b, "hedged runs replay byte-identically");
}
