//! Sweep determinism: running a ported experiment with `--jobs 4` must
//! produce byte-identical tables/JSON to `--jobs 1` (acceptance
//! criterion for the parallel sweep executor).

use cpuslow::config::{ModelSpec, SystemSpec};
use cpuslow::experiments::fig7;
use cpuslow::sweep::{seeded_cells, Sweep};
use cpuslow::workload::AvSpec;

fn tiny_spec() -> AvSpec {
    // Small enough to run in test time, loaded enough that the scarce
    // cell actually contends (8 rps × 28k tokens × 15 µs ≈ 3.4 core-s/s).
    AvSpec {
        attacker_sl: 28_000,
        victim_sl: 2_800,
        rps: 8.0,
        attack_secs: 6.0,
        victim_start_secs: 2.0,
        n_victims: 1,
        max_new_tokens: 4,
        timeout_secs: 30.0,
    }
}

fn fig7_output(jobs: usize) -> String {
    let sys = SystemSpec::blackwell();
    let model = ModelSpec::llama31_8b();
    let cells = fig7::grid_cells(&sys, &model, 4, 8.0, &[5, 16], &[28_000], &tiny_spec());
    let results = Sweep::new("test", jobs).quiet(true).run(cells, fig7::run_cell);
    let table = fig7::render_cells("determinism check", &results).render();
    let json = fig7::cells_to_json(&results).to_string_pretty();
    table + &json
}

#[test]
fn fig7_grid_byte_identical_serial_vs_parallel() {
    let serial = fig7_output(1);
    let parallel = fig7_output(4);
    assert!(!serial.is_empty());
    assert_eq!(serial, parallel);
}

#[test]
fn repeated_parallel_runs_are_stable() {
    // Not just serial == parallel: parallel must equal parallel, i.e.
    // nothing in a cell depends on scheduling order.
    assert_eq!(fig7_output(3), fig7_output(3));
}

#[test]
fn seeded_cells_are_schedule_independent() {
    let a = seeded_cells(7, (0..32).collect::<Vec<u64>>());
    let seeds: Vec<u64> = a.iter().map(|c| c.seed).collect();
    // run the seeds through a parallel sweep; outputs must line up with
    // the per-index seeds regardless of which worker ran which cell
    let out = Sweep::new("seeds", 4)
        .quiet(true)
        .run(a, |cell| (cell.index, cell.seed));
    for (i, (index, seed)) in out.into_iter().enumerate() {
        assert_eq!(index, i);
        assert_eq!(seed, seeds[i]);
    }
}
