//! Acceptance tests for the attribution profiler (`src/profile/`):
//! conservation (phase spans partition wall time exactly; per-GPU
//! busy + sync + idle partitions elapsed), invisibility (profiling on
//! vs off leaves every outcome and report field byte-identical), causal
//! sanity (cheaper tokenization strictly improves TTFT p99 where
//! tokenization is the bottleneck; a ±0% scale is an exact no-op), and
//! determinism (the whatif grid and the diagnose rendering are
//! byte-identical across `--jobs` values and across reruns).

use cpuslow::config::{ModelSpec, RunConfig, SystemSpec};
use cpuslow::engine::{Outcome, ReqClass, ServingSim, StreamArrival};
use cpuslow::profile::{diagnose, whatif, N_PHASES};
use cpuslow::sweep::Sweep;
use cpuslow::workload::scenario::{run_scenario, Scenario, ScenarioReport};

fn cfg(cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 4, cores)
}

fn profiled_cfg(cores: usize) -> RunConfig {
    let mut c = cfg(cores);
    c.serve.profile = true;
    c
}

/// Tentpole invariant #1: attribution loses nothing and invents
/// nothing. For every catalog scenario — single-engine, fleet,
/// fault-injected — every terminal attempt's six phase spans sum to
/// exactly its wall time, and every GPU's busy + collective-sync +
/// idle slices sum to exactly the elapsed virtual clock.
#[test]
fn phase_spans_and_gpu_slices_conserve_time_across_catalog() {
    for scenario in Scenario::catalog() {
        let scenario = scenario.with_duration(6.0);
        let report = run_scenario(profiled_cfg(8), &scenario, 11);
        let p = report
            .profile
            .as_ref()
            .unwrap_or_else(|| panic!("{}: profile armed but absent", scenario.name));
        assert_eq!(p.dropped_records, 0, "{}", scenario.name);
        assert_eq!(
            p.per_request.len() as u64,
            p.requests,
            "{}: retained rows vs attempt count",
            scenario.name
        );
        if report.issued > 0 {
            assert!(p.requests > 0, "{}: no attempts recorded", scenario.name);
        }
        for rp in &p.per_request {
            assert!(rp.end_ns >= rp.arrival_ns, "{}", scenario.name);
            assert_eq!(
                rp.sum_ns(),
                rp.wall_ns(),
                "{}: request {} phases {:?} sum {} != wall {}",
                scenario.name,
                rp.id,
                rp.phase_ns,
                rp.sum_ns(),
                rp.wall_ns()
            );
        }
        assert!(!p.gpus.is_empty(), "{}", scenario.name);
        for g in &p.gpus {
            assert_eq!(
                g.busy_ns + g.sync_ns + g.idle_ns,
                g.elapsed_ns,
                "{}: replica {} rank {} busy {} + sync {} + idle {} != elapsed {}",
                scenario.name,
                g.replica,
                g.rank,
                g.busy_ns,
                g.sync_ns,
                g.idle_ns,
                g.elapsed_ns
            );
            assert!(g.elapsed_ns > 0, "{}", scenario.name);
        }
        // The report's totals are consistent with its own rows.
        let shares = p.phase_shares();
        let share_sum: f64 = shares.iter().sum();
        assert!(
            p.requests == 0 || (share_sum - 1.0).abs() < 1e-9,
            "{}: phase shares sum to {share_sum}",
            scenario.name
        );
        assert_eq!(shares.len(), N_PHASES);
    }
}

fn outcomes_with_profile(profile: bool, scenario: &Scenario, seed: u64) -> Vec<Outcome> {
    let mut config = cfg(8);
    config.serve.profile = profile;
    let mut sim = ServingSim::new(config);
    let mut out = Vec::new();
    let arrivals: Vec<StreamArrival> = scenario
        .generate(seed)
        .requests
        .iter()
        .map(|r| StreamArrival {
            at_ns: r.at_ns,
            class: ReqClass::Normal,
            prompt_tokens: r.prompt_tokens,
            max_new_tokens: r.output_tokens,
            content_seed: r.content_seed,
            tag: r.class_idx as u32,
        })
        .collect();
    sim.run_streaming(arrivals.into_iter(), 20.0, |o| out.push(o));
    out.sort_by_key(|o| o.id);
    out
}

fn assert_reports_identical(a: &ScenarioReport, b: &ScenarioReport, label: &str) {
    assert_eq!(a.issued, b.issued, "{label}");
    assert_eq!(a.timeouts, b.timeouts, "{label}");
    assert_eq!(a.shed, b.shed, "{label}");
    assert_eq!(a.rejected, b.rejected, "{label}");
    assert_eq!(a.aborted, b.aborted, "{label}");
    assert_eq!(a.retries, b.retries, "{label}");
    assert_eq!(a.steps_completed, b.steps_completed, "{label}");
    assert_eq!(a.replicas, b.replicas, "{label}");
    assert_eq!(
        a.ttft_p50_s.map(f64::to_bits),
        b.ttft_p50_s.map(f64::to_bits),
        "{label}"
    );
    assert_eq!(
        a.ttft_p99_s.map(f64::to_bits),
        b.ttft_p99_s.map(f64::to_bits),
        "{label}"
    );
    assert_eq!(
        a.gpu_idle_share.to_bits(),
        b.gpu_idle_share.to_bits(),
        "{label}"
    );
    assert_eq!(a.wall_secs.to_bits(), b.wall_secs.to_bits(), "{label}");
    assert_eq!(
        a.cpu_core_seconds.to_bits(),
        b.cpu_core_seconds.to_bits(),
        "{label}"
    );
}

/// Tentpole invariant #2: profiling is free and invisible. Arming
/// `serve.profile` must not move a single timestamp — every
/// per-request Outcome and every report field is byte-identical with
/// profiling on and off, on both the single-engine and fleet paths.
#[test]
fn profiling_on_vs_off_is_byte_identical() {
    for name in ["steady", "multi-tenant", "attack"] {
        let scenario = Scenario::by_name(name).unwrap().with_duration(6.0);
        let off = outcomes_with_profile(false, &scenario, 3);
        let on = outcomes_with_profile(true, &scenario, 3);
        assert!(!off.is_empty(), "{name}");
        assert_eq!(off, on, "{name}: outcomes diverged under profiling");
    }
    // Fleet path (failover + retries active), via the scenario driver.
    for name in ["degraded-tokenizer", "replica-failure-with-failover"] {
        let scenario = Scenario::by_name(name).unwrap().with_duration(6.0);
        let off = run_scenario(cfg(8), &scenario, 5);
        let on = run_scenario(profiled_cfg(8), &scenario, 5);
        assert!(off.profile.is_none(), "{name}");
        assert!(on.profile.is_some(), "{name}");
        assert_reports_identical(&off, &on, name);
    }
}

/// Causal sanity on the scenario whose paper section *is* tokenization
/// share of TTFT: halving the tokenize cost on heavy-tail (Zipf
/// prompts up to 114k tokens) at a starved core count must strictly
/// improve TTFT p99, and setting every scale to exactly 1.0 must be a
/// bit-exact no-op versus a config that never touched the scales.
#[test]
fn tokenize_half_cost_strictly_improves_heavy_tail_p99() {
    let scenario = Scenario::by_name("heavy-tail").unwrap().with_duration(10.0);
    let base = run_scenario(cfg(5), &scenario, 7);
    let mut faster = cfg(5);
    faster.scales.tokenize = 0.5;
    let fast = run_scenario(faster, &scenario, 7);
    assert_eq!(base.issued, fast.issued);
    assert_eq!(base.timeouts, 0, "run must stay uncensored");
    assert_eq!(fast.timeouts, 0, "run must stay uncensored");
    let (b, f) = (
        base.ttft_p99_s.expect("on-time requests"),
        fast.ttft_p99_s.expect("on-time requests"),
    );
    assert!(
        f < b,
        "halving tokenize cost did not improve p99: {b:.4} -> {f:.4}"
    );

    // ±0%: explicitly writing 1.0 into every scale is indistinguishable
    // from never touching them (`scale_ns` short-circuits at 1.0).
    let mut unit = cfg(5);
    unit.scales.tokenize = 1.0;
    unit.scales.launch = 1.0;
    unit.scales.comm = 1.0;
    unit.scales.compute = 1.0;
    let unit_report = run_scenario(unit, &scenario, 7);
    assert_reports_identical(&base, &unit_report, "unit scales");
}

/// The whatif causal grid is a pure function of (config, scenarios,
/// components, delta, seed): byte-identical across `--jobs 1` and
/// `--jobs 3`, and across reruns.
#[test]
fn whatif_grid_byte_identical_across_jobs_and_reruns() {
    let config = cfg(8);
    let scenarios: Vec<Scenario> = ["steady", "heavy-tail"]
        .iter()
        .map(|n| Scenario::by_name(n).unwrap().with_duration(5.0))
        .collect();
    let components = ["tokenize", "launch", "comm"];
    let grid = |jobs: usize| {
        let sweep = Sweep::new("test-whatif", jobs).quiet(true);
        let rows = whatif::compute(&config, &scenarios, &components, 0.25, 2, &sweep);
        whatif::render(&rows, 0.25)
    };
    let serial = grid(1);
    let threaded = grid(3);
    let rerun = grid(1);
    assert!(serial.contains("tokenize"));
    assert!(serial.contains("heavy-tail"));
    assert_eq!(serial, threaded, "whatif output depends on --jobs");
    assert_eq!(serial, rerun, "whatif output differs across reruns");
    // Every (scenario × component) row reports a finite derivative on
    // these uncensored short runs.
    let sweep = Sweep::new("test-whatif", 1).quiet(true);
    let rows = whatif::compute(&config, &scenarios, &components, 0.25, 2, &sweep);
    assert_eq!(rows.len(), scenarios.len() * components.len());
    for r in &rows {
        let d = r
            .derivative_s()
            .unwrap_or_else(|| panic!("{}/{}: no derivative", r.scenario, r.component));
        assert!(d.is_finite(), "{}/{}", r.scenario, r.component);
    }
}

/// Golden-output pin for `cpuslow diagnose` on the starved-5-core
/// steady scenario. The rendering is a pure function of the report, so
/// two renders of two identical runs must match byte-for-byte; the
/// committed golden file (when captured) pins the exact bytes across
/// refactors. An empty golden file skips only the byte-compare and
/// prints the current rendering so it can be committed.
#[test]
fn diagnose_starved_steady_golden() {
    let golden = include_str!("golden/diagnose_steady_5core.golden.txt");
    let scenario = Scenario::by_name("steady").unwrap().with_duration(6.0);
    let render_once = || {
        let report = run_scenario(profiled_cfg(5), &scenario, 0);
        diagnose::render(&report, 0)
    };
    let a = render_once();
    let b = render_once();
    assert_eq!(a, b, "diagnose rendering differs across reruns");
    assert!(a.starts_with("Diagnosis: scenario 'steady'"), "{a}");
    for needle in [
        "Per-request phase attribution",
        "Per-GPU attribution",
        "CPU time by task class",
        "trace ring:",
        "suggestion:",
    ] {
        assert!(a.contains(needle), "missing '{needle}' in:\n{a}");
    }
    if golden.trim().is_empty() {
        eprintln!(
            "golden file empty — commit the following to \
             tests/golden/diagnose_steady_5core.golden.txt:\n{a}"
        );
    } else {
        assert_eq!(a, golden, "diagnose output drifted from the golden file");
    }
}
