//! Overload-survival acceptance tests: priority scheduling with
//! KV-pressure recompute preemption, the priority tokenizer queue, and
//! the brownout degradation ladder.
//!
//! The contract under test has two halves. Armed, the priority layer
//! must *visibly* protect the latency-critical class on starved cores
//! without starving batch work forever. Disabled (every gate off — the
//! default), the layer must be a byte-exact no-op: class priorities are
//! ignored and every report matches the pre-priority path.

use cpuslow::config::{ModelSpec, RunConfig, ServeConfig, SystemSpec};
use cpuslow::experiments::serve_sweep;
use cpuslow::sweep::{seeded_cells, Sweep};
use cpuslow::workload::scenario::{run_trace, ClassReport, Scenario, ScenarioReport, Trace};

fn cfg(cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, cores)
}

fn assert_reports_equal(a: &ScenarioReport, b: &ScenarioReport, what: &str) {
    assert_eq!(a.issued, b.issued, "{what}: issued");
    assert_eq!(a.timeouts, b.timeouts, "{what}: timeouts");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.rejected, b.rejected, "{what}: rejected");
    assert_eq!(a.aborted, b.aborted, "{what}: aborted");
    assert_eq!(a.retries, b.retries, "{what}: retries");
    assert_eq!(a.preemptions, b.preemptions, "{what}: preemptions");
    assert_eq!(a.brownout_windows, b.brownout_windows, "{what}: brownout");
    assert_eq!(a.ttft_p50_s, b.ttft_p50_s, "{what}: p50");
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s, "{what}: p99");
    assert_eq!(a.steps_completed, b.steps_completed, "{what}: steps");
}

fn class<'a>(report: &'a ScenarioReport, name: &str) -> &'a ClassReport {
    report
        .per_class
        .iter()
        .find(|c| c.name == name)
        .unwrap_or_else(|| panic!("report missing class '{name}'"))
}

/// Acceptance criterion: on starved cores, arming the priority layer
/// strictly improves chat's tail service — fewer SLO misses and a lower
/// on-time TTFT p99 — while bulk keeps making progress (degraded, not
/// starved forever).
#[test]
fn priority_protects_chat_on_starved_cores() {
    // 2× the catalog rates saturates the 5-core tokenizer through the
    // bulk bursts (same pressure recipe as the resilience tests), so
    // the priority-off run visibly misses chat SLOs.
    let armed = Scenario::by_name("priority-flash-crowd")
        .unwrap()
        .scaled(2.0)
        .with_duration(15.0)
        .generate(3);
    let mut disarmed = armed.clone();
    disarmed.priority = None;
    let on = run_trace(cfg(5), &armed);
    let off = run_trace(cfg(5), &disarmed);
    assert_eq!(on.issued, off.issued, "same trace, same request count");

    let chat_on = class(&on, "chat");
    let chat_off = class(&off, "chat");
    assert!(
        chat_off.timeouts > 0,
        "overload recipe must make priority-off chat miss SLOs \
         (got 0 — the pressure knobs drifted)"
    );
    assert!(
        chat_on.timeouts < chat_off.timeouts,
        "priority must strictly cut chat SLO misses: {} vs {}",
        chat_on.timeouts,
        chat_off.timeouts
    );
    let p99_on = chat_on.ttft_p99_s.expect("armed chat serves on time");
    let p99_off = chat_off.ttft_p99_s.expect("some disarmed chat is on time");
    assert!(
        p99_on < p99_off,
        "priority must strictly improve chat on-time TTFT p99: \
         {p99_on:.3} vs {p99_off:.3}"
    );

    // Survival machinery actually engaged — the win must come from the
    // ladder, not from noise.
    assert!(
        on.preemptions > 0 || on.brownout_windows > 0,
        "armed run never preempted nor browned out"
    );
    assert_eq!(off.preemptions, 0, "disarmed run cannot preempt");
    assert_eq!(off.brownout_windows, 0, "disarmed run cannot brown out");

    // Graceful degradation, not starvation: every bulk request still
    // reaches a terminal outcome and not all of them are shed.
    let bulk_on = class(&on, "bulk");
    assert_eq!(bulk_on.issued, class(&off, "bulk").issued);
    assert!(
        bulk_on.shed < bulk_on.issued,
        "brownout must not shed the entire bulk class ({} of {})",
        bulk_on.shed,
        bulk_on.issued
    );
}

/// With every priority gate off (the default config), class priorities
/// are inert inputs: a trace whose classes carry tiers reports
/// byte-identically to the same trace with the tiers zeroed. That is
/// the disabled-path no-op guarantee — the scheduler walks the same
/// FCFS order, the tokenizer pool stays FIFO, no brownout runs.
#[test]
fn disabled_gates_ignore_class_priorities() {
    let mut tiered = Scenario::by_name("priority-flash-crowd")
        .unwrap()
        .with_duration(6.0)
        .generate(11);
    tiered.priority = None; // gates off; class tiers (2 vs 0) remain
    let mut flat = tiered.clone();
    for c in &mut flat.classes {
        c.priority = 0;
    }
    let a = run_trace(cfg(8), &tiered);
    let b = run_trace(cfg(8), &flat);
    assert_reports_equal(&a, &b, "gates-off tiered vs flat");
    assert_eq!(a.preemptions, 0);
    assert_eq!(a.brownout_windows, 0);
}

/// Recompute preemption preserves request identity: a preempted victim
/// is re-queued, not re-issued, so the run emits exactly one terminal
/// outcome per generated request — and every evicted KV page is back in
/// the free pool at the horizon.
#[test]
fn preempted_requests_emit_exactly_one_outcome() {
    let trace = Scenario::by_name("kv-thrash").unwrap().with_duration(12.0).generate(3);
    let report = run_trace(cfg(8), &trace);
    assert!(
        report.preemptions > 0,
        "kv-thrash must exhaust KV and force preemptions"
    );
    // One terminal outcome per trace request: preemption never
    // duplicates (or swallows) a request.
    assert_eq!(report.issued, trace.requests.len(), "exactly-one-outcome");
    // Preemptions land on the evicted hogs, not the protected chat.
    assert!(class(&report, "hog").preemptions > 0, "hogs take the evictions");
    cpuslow::testkit::assert_no_kv_leak(&report);
    // kv-thrash arms scheduling only — the ladder must stay cold.
    assert_eq!(report.brownout_windows, 0, "preemption-only scenario");
}

/// A dumped kv-thrash trace replays byte-identically: the priority
/// gates and class tiers ride in the JSON, so preemption decisions
/// reproduce exactly from the dump.
#[test]
fn dumped_kv_thrash_replays_byte_identically() {
    let trace = Scenario::by_name("kv-thrash").unwrap().with_duration(8.0).generate(5);
    let dump = trace.to_json().to_string_pretty();
    let parsed = cpuslow::util::json::parse(&dump).unwrap();
    let back = Trace::from_json(&parsed).unwrap();
    assert_eq!(back, trace, "round-trip equality");
    assert_eq!(back.to_json().to_string_pretty(), dump, "byte-stable dump");
    let a = run_trace(cfg(8), &trace);
    let b = run_trace(cfg(8), &back);
    assert!(a.preemptions > 0, "replay must exercise the preemption path");
    assert_reports_equal(&a, &b, "kv-thrash replay");
}

fn sweep_output(jobs: usize) -> String {
    let scenarios = vec![
        Scenario::by_name("priority-flash-crowd").unwrap().with_duration(6.0),
        Scenario::by_name("kv-thrash").unwrap().with_duration(6.0),
    ];
    let specs = serve_sweep::grid(
        &scenarios,
        &SystemSpec::blackwell(),
        &ModelSpec::llama31_8b(),
        &ServeConfig::default(),
        &[4],
        Some(&[5, 16]),
        &[1],
        &[],
    );
    let cells = seeded_cells(0, specs);
    let results = Sweep::new("test", jobs)
        .quiet(true)
        .run(cells, serve_sweep::run_cell);
    let table = serve_sweep::render_cells("priority determinism", &results).render();
    let json = serve_sweep::cells_to_json(&results).to_string_pretty();
    table + &json
}

/// Preemption and brownout decisions key off deterministic engine state
/// (admission order, probe-window indices), never worker schedule — so
/// a priority-armed sweep stays byte-identical across `--jobs` values.
#[test]
fn priority_sweep_jobs_byte_identical() {
    let serial = sweep_output(1);
    let parallel = sweep_output(3);
    assert!(serial.contains("preempts"), "sweep table carries the preempt column");
    assert!(serial.contains("brownout"), "sweep table carries the brownout column");
    assert_eq!(serial, parallel);
}
