//! Disaggregated prefill/decode pool acceptance tests: a pools-disabled
//! fleet is byte-identical to the colocated path no matter what the
//! transfer knobs say, transfer loss always resolves to exactly one
//! terminal outcome per request (re-prefill fallback), decode-pool loss
//! degrades gracefully to colocated serving, disagg sweeps stay
//! byte-identical across `--jobs`, and dumped disagg traces replay
//! exactly (pools topology included).

use cpuslow::config::{FleetConfig, ModelSpec, PoolConfig, RouterPolicy, RunConfig, ServeConfig,
                      SystemSpec};
use cpuslow::engine::{FaultSpec, OutcomeStatus, ReqClass, StreamArrival};
use cpuslow::experiments::serve_sweep;
use cpuslow::fleet::FleetSim;
use cpuslow::sweep::{seeded_cells, Sweep};
use cpuslow::testkit::assert_no_kv_leak;
use cpuslow::workload::scenario::{run_trace, Scenario, ScenarioReport, Trace};

fn cfg(n_gpus: usize, cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), n_gpus, cores)
}

fn assert_reports_equal(a: &ScenarioReport, b: &ScenarioReport, what: &str) {
    assert_eq!(a.issued, b.issued, "{what}: issued");
    assert_eq!(a.timeouts, b.timeouts, "{what}: timeouts");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.rejected, b.rejected, "{what}: rejected");
    assert_eq!(a.aborted, b.aborted, "{what}: aborted");
    assert_eq!(a.retries, b.retries, "{what}: retries");
    assert_eq!(a.ttft_p50_s, b.ttft_p50_s, "{what}: p50");
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s, "{what}: p99");
    assert_eq!(a.steps_completed, b.steps_completed, "{what}: steps");
    assert_eq!(a.pools, b.pools, "{what}: pool counters");
}

/// Acceptance criterion: with pools disabled the colocated fleet path
/// is untouched — even exotic transfer knobs on a disabled `[fleet.pools]`
/// block must not perturb a single outcome, step, or retry.
#[test]
fn disabled_pools_leave_the_colocated_fleet_byte_identical() {
    let trace = Scenario::by_name("replica-failure-with-failover")
        .unwrap()
        .with_duration(6.0)
        .generate(4);
    let base = run_trace(cfg(2, 8), &trace);
    assert!(base.issued > 0);
    assert!(base.pools.is_none(), "colocated fleet reports no pool summary");

    let mut knobs_trace = trace.clone();
    let mut fleet = knobs_trace.fleet.take().unwrap();
    // Disabled partition (0/0) with deliberately hostile knob values.
    fleet.pools = PoolConfig {
        prefill: 0,
        decode: 0,
        transfer_gb_per_s: 0.001,
        transfer_base_s: 5.0,
        transfer_max_attempts: 1,
        max_inflight_per_decode: 1,
    };
    knobs_trace.fleet = Some(fleet);
    let with_knobs = run_trace(cfg(2, 8), &knobs_trace);
    assert_reports_equal(&base, &with_knobs, "disabled pools");
}

/// Acceptance criterion: with TransferLoss at p=1.0 every handoff
/// exhausts its retry budget and falls back to re-prefilling in the
/// decode pool — yet every request still ends in exactly one terminal
/// Completed outcome with its full token budget, and no KV page leaks.
#[test]
fn transfer_loss_resolves_every_request_via_reprefill() {
    let mut run_cfg = cfg(2, 9);
    run_cfg.serve.fleet = FleetConfig {
        replicas: 3,
        router: RouterPolicy::LeastLoaded,
        pools: PoolConfig {
            prefill: 1,
            decode: 2,
            transfer_max_attempts: 2,
            ..PoolConfig::default()
        },
        ..FleetConfig::default()
    };
    let mut sim = FleetSim::new(run_cfg);
    sim.set_run_seed(11);
    sim.install_faults(&[FaultSpec::TransferLoss {
        start_s: 0.0,
        end_s: 600.0,
        prob: 1.0,
        replica: None,
    }]);
    let n = 6u64;
    for i in 0..n {
        sim.submit_request(StreamArrival {
            at_ns: i * 250_000_000,
            class: ReqClass::Normal,
            prompt_tokens: 400,
            max_new_tokens: 8,
            content_seed: i,
            tag: 0,
        });
    }
    sim.run_secs(120.0);
    let outcomes = sim.drain_outcomes();
    assert_eq!(outcomes.len(), n as usize, "exactly one outcome per request");
    let mut origins: Vec<u64> = outcomes.iter().map(|o| o.origin).collect();
    origins.sort_unstable();
    origins.dedup();
    assert_eq!(origins.len(), n as usize, "origins are unique");
    for o in &outcomes {
        assert_eq!(o.status, OutcomeStatus::Completed, "origin {}", o.origin);
        assert_eq!(o.generated_tokens, 8, "origin {}", o.origin);
        assert!(o.retries >= 1, "re-prefill must count as a retry ({})", o.origin);
    }
    let s = sim.pool_summary().expect("pools are armed");
    assert_eq!(s.prefill_replicas, 1);
    assert_eq!(s.decode_replicas, 2);
    assert_eq!(s.handoffs_started, n, "every request attempts a handoff");
    assert_eq!(s.handoffs_completed, 0, "p=1.0 loss lets none land");
    assert_eq!(s.transfer_retries, n, "one in-budget retry per request");
    assert_eq!(s.transfer_failures, n, "then the budget is exhausted");
    assert_eq!(s.reprefills, n, "every request falls back to re-prefill");
    assert_eq!(sim.kv_pages_in_use(), 0, "no KV page leaks at horizon");
}

/// Losing the decode pool's only replica mid-run trips colocated
/// fallback: probes mark the pool Down, new arrivals serve colocated,
/// and the run still drains without leaking KV pages.
#[test]
fn decode_pool_loss_degrades_to_colocated_serving() {
    let trace = Scenario::by_name("disagg-decode-pool-loss").unwrap().generate(3);
    let report = run_trace(cfg(2, 8), &trace);
    assert!(report.issued > 0);
    let pools = report.pools.expect("scenario arms pools");
    assert!(
        pools.colocated_windows > 0,
        "decode-pool brown-out must trip colocated mode: {pools:?}"
    );
    assert!(
        pools.colocated_fallbacks > 0,
        "arrivals during the outage must serve colocated: {pools:?}"
    );
    assert!(pools.handoffs_completed > 0, "healthy phases still hand off");
    assert_no_kv_leak(&report);
}

fn disagg_sweep_output(jobs: usize) -> String {
    let scenarios = vec![
        Scenario::by_name("disagg-steady").unwrap().with_duration(6.0),
        Scenario::by_name("disagg-transfer-faults").unwrap().with_duration(6.0),
    ];
    let specs = serve_sweep::grid(
        &scenarios,
        &SystemSpec::h100(),
        &ModelSpec::llama31_8b(),
        &ServeConfig::default(),
        &[2],
        Some(&[6]),
        &[1],
        &[],
    );
    let cells = seeded_cells(0, specs);
    let results = Sweep::new("test", jobs)
        .quiet(true)
        .run(cells, serve_sweep::run_cell);
    serve_sweep::render_cells("disagg determinism", &results).render()
        + &serve_sweep::cells_to_json(&results).to_string_pretty()
}

/// Acceptance criterion: handoff scheduling, transfer fault draws, and
/// backpressure deferrals are all pure functions of (seed, origin,
/// attempt) — so a disagg sweep's bytes cannot depend on `--jobs`.
#[test]
fn disagg_sweep_jobs_byte_identical() {
    let serial = disagg_sweep_output(1);
    let parallel = disagg_sweep_output(3);
    assert!(serial.contains("disagg-steady"));
    assert_eq!(serial, parallel);
}

/// A dumped disagg trace carries its pools topology and replays
/// byte-identically — outcomes, retry ledger, pool counters and all.
#[test]
fn disagg_trace_replays_byte_identically() {
    let trace = Scenario::by_name("disagg-transfer-faults")
        .unwrap()
        .with_duration(8.0)
        .generate(6);
    let a = run_trace(cfg(2, 8), &trace);
    assert!(a.issued > 0);
    let pools = a.pools.expect("pools armed");
    assert!(pools.handoffs_started > 0, "handoffs happen: {pools:?}");

    let dump = trace.to_json().to_string_pretty();
    assert!(dump.contains("\"pools\""), "dump carries the pool partition");
    let parsed = cpuslow::util::json::parse(&dump).unwrap();
    let replay = Trace::from_json(&parsed).unwrap();
    assert_eq!(replay, trace, "pools topology survives the dump");

    let b = run_trace(cfg(2, 8), &replay);
    assert_reports_equal(&a, &b, "disagg replay");
    assert_no_kv_leak(&a);
}
