//! Property-based tests on coordinator invariants, using the in-repo
//! `testkit` framework (routing, batching, state management — the L3
//! invariants the repro contract calls out).

use cpuslow::config::ServeConfig;
use cpuslow::engine::{
    complete_step, schedule, KvCache, PrefixCache, ReqClass, Request, SchedState,
};
use cpuslow::simcpu::script::Script;
use cpuslow::simcpu::{Sim, SimParams};
use cpuslow::testkit::{self, PairGen, U64Range, VecGen};
use cpuslow::util::rng::Rng;

fn cfg() -> ServeConfig {
    ServeConfig {
        prefill_chunk_tokens: 256,
        max_batch_size: 8,
        kv_page_tokens: 16,
        kv_pages_per_gpu: 256, // small so exhaustion paths exercise
        prefix_caching: false,
        ..Default::default()
    }
}

/// Drive the scheduler to completion over a generated request mix and
/// check conservation invariants at every step.
fn run_schedule_to_completion(reqs: &[(u64, u64)]) -> bool {
    let mut state = SchedState::new();
    let mut kv = KvCache::new(16, 256);
    let cfg = cfg();
    for (i, &(prompt, out)) in reqs.iter().enumerate() {
        state.enqueue(Request::new(
            i as u64,
            ReqClass::Normal,
            0,
            prompt.max(1),
            out.max(1),
        ));
    }
    let mut now = 0u64;
    let mut steps = 0;
    loop {
        let plan = schedule(&mut state, &mut kv, None, &cfg, now);
        // invariant: KV pages conserved after scheduling
        if !kv.check_conservation() {
            return false;
        }
        let Some(plan) = plan else { break };
        // invariant: step token budget respected
        if plan.prefill_tokens() + plan.decode.len() as u64 > cfg.prefill_chunk_tokens as u64 {
            return false;
        }
        // invariant: batch bound respected
        if plan.batch_size() > cfg.max_batch_size {
            return false;
        }
        // invariant: no request appears in both prefill and decode
        for &(id, _, _) in &plan.prefill {
            if plan.decode.contains(&id) {
                return false;
            }
        }
        now += 1_000_000;
        complete_step(&mut state, &mut kv, &plan, now);
        if !kv.check_conservation() {
            return false;
        }
        steps += 1;
        if steps > 200_000 {
            return false; // livelock
        }
    }
    // all requests that fit KV must have finished; none lost
    let total = state.requests.len();
    let finished = state.requests.values().filter(|r| r.is_done()).count();
    let waiting = state.n_waiting();
    // every non-finished request must still be waiting (stuck on KV),
    // and only requests too large for the cache may be stuck forever
    let stuck_ok = state
        .requests
        .values()
        .filter(|r| !r.is_done())
        .all(|r| (r.prompt_tokens + r.max_new_tokens) > (256 * 16) as u64 || waiting > 0);
    finished + waiting == total && stuck_ok && kv.used_pages() == 0 || waiting > 0
}

#[test]
fn prop_scheduler_conserves_and_terminates() {
    let gen = VecGen {
        elem: PairGen {
            a: U64Range { lo: 1, hi: 3_000 }, // prompt tokens
            b: U64Range { lo: 1, hi: 24 },    // output tokens
        },
        min_len: 1,
        max_len: 24,
    };
    testkit::check_with(
        testkit::Config {
            cases: 60,
            ..Default::default()
        },
        &gen,
        |reqs| run_schedule_to_completion(reqs),
    );
}

#[test]
fn prop_kv_cache_grow_release_conservation() {
    // random interleavings of grow/release never lose pages
    let gen = VecGen {
        elem: PairGen {
            a: U64Range { lo: 0, hi: 9 },   // request id
            b: U64Range { lo: 0, hi: 600 }, // tokens (0 → release)
        },
        min_len: 1,
        max_len: 64,
    };
    testkit::check(&gen, |ops| {
        let mut kv = KvCache::new(16, 128);
        for &(id, tokens) in ops {
            if tokens == 0 {
                kv.release(id);
            } else {
                let _ = kv.grow_to(id, tokens);
            }
            if !kv.check_conservation() {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_prefix_cache_skip_never_exceeds_prompt() {
    let gen = VecGen {
        elem: PairGen {
            a: U64Range { lo: 0, hi: 5 },     // content seed
            b: U64Range { lo: 1, hi: 2_000 }, // prompt tokens
        },
        min_len: 1,
        max_len: 40,
    };
    testkit::check(&gen, |reqs| {
        let mut pc = PrefixCache::new(16, 512);
        for &(seed, prompt) in reqs {
            let skipped = pc.lookup_and_insert(seed, prompt);
            if skipped > prompt {
                return false;
            }
            if skipped % 16 != 0 {
                return false; // only whole pages cacheable
            }
        }
        true
    });
}

#[test]
fn prop_sim_work_conservation() {
    // Total CPU charged to compute-only tasks equals requested work,
    // regardless of core count and task mix.
    let gen = PairGen {
        a: U64Range { lo: 1, hi: 6 }, // cores
        b: VecGen {
            elem: U64Range {
                lo: 100_000,
                hi: 20_000_000,
            }, // per-task ns
            min_len: 1,
            max_len: 12,
        },
    };
    testkit::check_with(
        testkit::Config {
            cases: 40,
            ..Default::default()
        },
        &gen,
        |(cores, works)| {
            let mut sim = Sim::new(SimParams {
                cores: *cores as usize,
                context_switch_ns: 0,
                timeslice_ns: 1_000_000,
                poll_quantum_ns: 1_000,
                trace_bucket_ns: None,
            });
            let ids: Vec<_> = works
                .iter()
                .map(|&w| sim.spawn("t", Script::new().compute(w)))
                .collect();
            sim.run();
            let total: u64 = ids.iter().map(|&id| sim.task_stats(id).cpu_ns).sum();
            let requested: u64 = works.iter().sum();
            total == requested && ids.iter().all(|&id| sim.task_finished(id))
        },
    );
}

#[test]
fn prop_sim_makespan_bounds() {
    // makespan ∈ [total/cores, total] for compute-only workloads
    let gen = PairGen {
        a: U64Range { lo: 1, hi: 8 },
        b: VecGen {
            elem: U64Range {
                lo: 500_000,
                hi: 10_000_000,
            },
            min_len: 1,
            max_len: 16,
        },
    };
    testkit::check_with(
        testkit::Config {
            cases: 40,
            ..Default::default()
        },
        &gen,
        |(cores, works)| {
            let mut sim = Sim::new(SimParams {
                cores: *cores as usize,
                context_switch_ns: 0,
                timeslice_ns: 1_000_000,
                poll_quantum_ns: 1_000,
                trace_bucket_ns: None,
            });
            for &w in works {
                sim.spawn("t", Script::new().compute(w));
            }
            let end = sim.run();
            let total: u64 = works.iter().sum();
            let lower = total / (*cores).max(1);
            let upper = total + works.len() as u64; // rounding slack
            end >= lower && end <= upper && end >= *works.iter().max().unwrap()
        },
    );
}

#[test]
fn prop_shm_broadcast_fifo_per_reader() {
    use cpuslow::ipc::ShmBroadcast;
    // random interleavings of enqueue/dequeue preserve FIFO per reader
    let gen = VecGen {
        elem: U64Range { lo: 0, hi: 3 }, // 0..=2 → reader dequeue; 3 → enqueue
        min_len: 1,
        max_len: 200,
    };
    testkit::check(&gen, |ops| {
        let q = ShmBroadcast::new(8, 3);
        let mut sent = 0u64;
        let mut expected = [0u64; 3];
        for &op in ops {
            if op == 3 {
                if q.try_enqueue(sent) {
                    sent += 1;
                }
            } else {
                let r = op as usize;
                if let Some(v) = q.try_dequeue(r) {
                    if v != expected[r] {
                        return false;
                    }
                    expected[r] += 1;
                }
            }
        }
        true
    });
}

#[test]
fn prop_rng_streams_stay_in_bounds() {
    let gen = PairGen {
        a: U64Range { lo: 1, hi: u64::MAX / 2 },
        b: U64Range { lo: 1, hi: 1_000 },
    };
    testkit::check(&gen, |(seed, n)| {
        let mut rng = Rng::new(*seed);
        (0..64).all(|_| rng.below(*n) < *n)
    });
}
