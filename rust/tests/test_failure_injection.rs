//! Failure-injection and edge-case tests: overload, exhaustion,
//! degenerate configs — the system must degrade predictably, not wedge.

use cpuslow::config::{ModelSpec, RunConfig, ServeConfig, SystemSpec};
use cpuslow::engine::{OutcomeStatus, ReqClass, ServingSim};

fn base_cfg(cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 4, cores)
}

#[test]
fn kv_exhaustion_queues_rather_than_crashing() {
    // Tiny KV: only ~2 requests fit; the rest must queue and finish later.
    let mut cfg = base_cfg(16);
    cfg.serve.kv_pages_per_gpu = 1_500; // 24k tokens
    cfg.serve.prefix_caching = false;
    let mut sim = ServingSim::new(cfg);
    let ids: Vec<_> = (0..6)
        .map(|i| sim.submit_at(i * 1_000_000, ReqClass::Normal, 10_000, 4))
        .collect();
    sim.run_secs(600.0);
    for id in ids {
        let o = sim.outcome(id).unwrap();
        assert!(
            o.e2e_ns.is_some(),
            "req {} should finish after queueing",
            o.id
        );
    }
}

#[test]
fn request_too_large_for_kv_starves_but_system_survives() {
    // A request whose prompt exceeds *total* KV capacity can never be
    // admitted. Admission control detects the permanent condition and
    // rejects it instead of letting FCFS head-of-line blocking wedge the
    // queue forever — the small request behind it must still complete.
    let mut cfg = base_cfg(16);
    cfg.serve.kv_pages_per_gpu = 100; // 1600 tokens total
    cfg.serve.prefix_caching = false;
    let mut sim = ServingSim::new(cfg);
    let huge = sim.submit_at(0, ReqClass::Normal, 50_000, 4); // can never fit
    let small = sim.submit_at(1_000_000, ReqClass::Normal, 500, 4);
    sim.run_secs(120.0);
    let o_huge = sim.outcome(huge).unwrap();
    assert_eq!(o_huge.status, OutcomeStatus::Rejected, "never-fit is rejected");
    assert!(o_huge.ttft_ns.is_none(), "oversized request cannot start");
    let o_small = sim.outcome(small).unwrap();
    assert!(
        o_small.e2e_ns.is_some(),
        "small request behind a rejected never-fit must complete"
    );
    assert_eq!(o_small.status, OutcomeStatus::Completed);
}

#[test]
fn single_core_single_gpu_minimal_config() {
    let cfg = RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 1, 1);
    let mut sim = ServingSim::new(cfg);
    let id = sim.submit_at(0, ReqClass::Normal, 1_000, 2);
    sim.run_secs(300.0);
    assert!(sim.outcome(id).unwrap().e2e_ns.is_some());
}

#[test]
fn zero_output_token_requests_rejected_by_finish_logic() {
    // max_new_tokens=1: first token finishes the request immediately.
    let mut sim = ServingSim::new(base_cfg(8));
    let id = sim.submit_at(0, ReqClass::Normal, 100, 1);
    sim.run_secs(60.0);
    let o = sim.outcome(id).unwrap();
    assert_eq!(o.generated_tokens, 1);
    assert_eq!(o.ttft_ns, o.e2e_ns);
}

#[test]
fn burst_of_duplicate_prompts_shares_prefix_cache() {
    let mut sim = ServingSim::new(base_cfg(32));
    let ids: Vec<_> = (0..8)
        .map(|i| sim.submit_with_seed(i * 5_000_000, ReqClass::Normal, 20_000, 4, 99))
        .collect();
    sim.run_secs(300.0);
    let ttfts: Vec<f64> = ids
        .iter()
        .map(|&id| sim.outcome(id).unwrap().ttft_secs().unwrap())
        .collect();
    // the first pays full prefill; later ones must be much cheaper
    let first = ttfts[0];
    let later_max = ttfts[2..].iter().cloned().fold(0.0, f64::max);
    assert!(
        later_max < first,
        "cached duplicates faster: first {first:.2}s, later max {later_max:.2}s"
    );
}

#[test]
fn cuda_graphs_off_increases_launch_load() {
    // With graphs disabled, decode steps need ~10× the launches; under
    // scarce cores this must visibly slow decode-heavy work.
    let run = |graphs: bool| {
        let mut cfg = base_cfg(5);
        cfg.serve.cuda_graphs = graphs;
        let mut sim = ServingSim::new(cfg);
        let id = sim.submit_at(0, ReqClass::Normal, 500, 64); // decode-heavy
        sim.run_secs(300.0);
        sim.outcome(id).unwrap().e2e_ns.unwrap()
    };
    let with = run(true);
    let without = run(false);
    assert!(
        without > with,
        "graphs off should be slower: {without} vs {with}"
    );
}

#[test]
fn invalid_configs_rejected() {
    let cfg = RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 4, 8);
    assert!(cfg.validate().is_ok());

    let mut bad = cfg.clone();
    bad.serve = ServeConfig {
        graph_dynamic_fraction: 2.0,
        ..Default::default()
    };
    assert!(bad.validate().is_err());

    let mut bad = cfg.clone();
    bad.cpu_cores = 1_000;
    assert!(bad.validate().is_err());

    let mut bad = cfg;
    bad.n_gpus = 3; // 32 heads % 3 != 0
    assert!(bad.validate().is_err());
}

#[test]
fn timeout_is_a_client_side_concept() {
    // The engine keeps serving even when a victim would have timed out:
    // submit an impossible victim load, run past the timeout, engine
    // still completes attacker work.
    let mut cfg = base_cfg(5);
    cfg.serve.kv_pages_per_gpu = 8_000;
    let mut sim = ServingSim::new(cfg);
    for i in 0..40u64 {
        sim.submit_with_seed(i * 125_000_000, ReqClass::Attacker, 114_000, 4, 7);
    }
    sim.run_secs(120.0);
    let finished_attackers = sim
        .outcomes()
        .iter()
        .filter(|o| o.class == ReqClass::Attacker && o.e2e_ns.is_some())
        .count();
    assert!(finished_attackers > 0, "engine still makes progress");
    assert!(sim.steps_completed() > 0);
}
