//! Allocation-behavior acceptance tests for the serving hot path,
//! measured with the counting global allocator from `testkit::alloc`.
//! Counters are per-thread, so these tests are immune to the libtest
//! harness running other tests concurrently.
//!
//! Utilization tracing is disabled (`ServingSim::with_options(.., false)`)
//! because traces grow with *virtual time* by design; everything else is
//! the production engine.

use cpuslow::config::{ModelSpec, RunConfig, SystemSpec};
use cpuslow::engine::{EngineCosts, ReqClass, ServingSim, StreamArrival};
use cpuslow::fleet::FleetSim;
use cpuslow::testkit::alloc::{self, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn cfg(n_gpus: usize, cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), n_gpus, cores)
}

#[test]
fn steady_state_engine_stepping_allocates_nothing() {
    // A fixed resident batch decoding for the whole measurement window:
    // no arrivals, no admissions, no finishes, no tokenizer activity —
    // pure engine/worker/device stepping. After warmup, the step path
    // (scheduler slab walk, pooled plan, shm ring gates, shared launch
    // and completion callbacks, collective churn) must not allocate.
    // Resilience armed but non-firing: the admission gate, shed
    // estimator, and deadline watchdog all run every scheduling pass yet
    // never trip (queue depth 4 ≪ 10k; 50× SLO budgets dwarf the
    // window). Their bookkeeping must ride the same zero-alloc path.
    let mut config = cfg(2, 8);
    config.serve.resilience.admission_max_queue = 10_000;
    config.serve.resilience.shed_slo_factor = 50.0;
    config.serve.resilience.watchdog_slo_factor = 50.0;
    config.serve.resilience.retry_max_attempts = 3;
    let mut sim = ServingSim::with_options(config, EngineCosts::default(), false);
    for i in 0..4u64 {
        // (512 + 100k) tokens ≈ 6.3k KV pages each — all four fit; the
        // 100k-token outputs keep them decoding far past the window.
        sim.submit_at(i * 1_000_000, ReqClass::Normal, 512, 100_000);
    }
    // Warmup: tokenize, admit, finish prefill, settle every pool and
    // capacity on the step path.
    sim.run_secs(5.0);
    let steps_before = sim.steps_completed();
    let before = alloc::counters();
    sim.run_secs(13.0);
    let after = alloc::counters();
    let steps = sim.steps_completed() - steps_before;
    assert!(steps > 100, "decode steps in the window: {steps}");
    assert_eq!(
        after.allocs - before.allocs,
        0,
        "steady-state stepping allocated ({} allocs / {} bytes over {steps} steps)",
        after.allocs - before.allocs,
        after.alloc_bytes - before.alloc_bytes,
    );
}

#[test]
fn fleet_steady_state_with_router_probes_and_autoscaler_allocates_nothing() {
    // Two full replicas on one substrate, router tick and health probes
    // firing every window, failure-aware transitions armed, and the
    // autoscaler armed but pinned (min == max == the static grant, so
    // no decision can ever fire and no limiter tasks exist). A resident
    // decode batch on each replica runs the measurement window: the
    // router tick (outbox drain, hedge scan, probe, autoscale check)
    // rides recycled scratch buffers and a recycled shared call, so the
    // fleet layer must add zero allocations to the engine steady state.
    let mut config = cfg(2, 8);
    config.serve.fleet.replicas = 2;
    config.serve.fleet.failure_aware = true;
    config.serve.fleet.autoscale = true;
    config.serve.fleet.min_cores_per_replica = 8;
    config.serve.fleet.max_cores_per_replica = 8;
    let mut sim = FleetSim::with_costs(config, EngineCosts::default());
    for i in 0..8u64 {
        // Round-robin spreads these 4-and-4; the 100k-token outputs
        // keep both replicas decoding far past the window.
        sim.submit_request(StreamArrival {
            at_ns: i * 1_000_000,
            class: ReqClass::Normal,
            prompt_tokens: 512,
            max_new_tokens: 100_000,
            content_seed: i,
            tag: 0,
        });
    }
    sim.run_secs(5.0);
    let steps_before = sim.steps_completed();
    let before = alloc::counters();
    sim.run_secs(13.0);
    let after = alloc::counters();
    let steps = sim.steps_completed() - steps_before;
    assert!(steps > 100, "decode steps in the window: {steps}");
    assert_eq!(
        after.allocs - before.allocs,
        0,
        "fleet steady-state stepping allocated ({} allocs / {} bytes over {steps} steps)",
        after.allocs - before.allocs,
        after.alloc_bytes - before.alloc_bytes,
    );
}

#[test]
fn profiled_steady_state_stepping_allocates_nothing() {
    // The same resident-batch steady state as above, but with the
    // attribution profiler armed: every simcpu dispatch, engine step,
    // and GPU launch records into the trace ring, which wraps and
    // sketch-folds evictions throughout the window. Profiling must be
    // free — the ring is preallocated, the fold sketches preallocate
    // their bins and exact buffers, and per-step phase charging only
    // mutates slab fields — so the armed run must match the unarmed
    // one's zero-allocation invariant exactly.
    let mut config = cfg(2, 8);
    config.serve.profile = true;
    let mut sim = ServingSim::with_options(config, EngineCosts::default(), false);
    for i in 0..4u64 {
        sim.submit_at(i * 1_000_000, ReqClass::Normal, 512, 100_000);
    }
    sim.run_secs(5.0);
    let steps_before = sim.steps_completed();
    let before = alloc::counters();
    sim.run_secs(13.0);
    let after = alloc::counters();
    let steps = sim.steps_completed() - steps_before;
    assert!(steps > 100, "decode steps in the window: {steps}");
    assert_eq!(
        after.allocs - before.allocs,
        0,
        "profiled steady-state stepping allocated ({} allocs / {} bytes over {steps} steps)",
        after.allocs - before.allocs,
        after.alloc_bytes - before.alloc_bytes,
    );
    // The window must actually have exercised ring wraparound: a 4096
    // record ring against >100 steps' worth of dispatch + step + launch
    // spans has long since started evicting into the fold sketches.
    let report = sim.profile_report().expect("profiling was armed");
    assert!(
        report.ring.evicted > 0,
        "ring never wrapped: {} records, capacity {}",
        report.ring.counts.iter().sum::<u64>(),
        report.ring.capacity
    );
}

#[test]
fn streaming_memory_roughly_constant_in_request_count() {
    // 10× the request volume through the streaming driver must not grow
    // peak live memory proportionally: finished requests are harvested
    // and evicted, slab pages are freed, and TTFT aggregation is
    // sketch-bounded. (Prefix caching off: its LRU grows toward a fixed
    // capacity with distinct prompts, which is bounded but would blur
    // this comparison.)
    let run = |n_requests: u64| -> i64 {
        let mut config = cfg(2, 16);
        config.serve.prefix_caching = false;
        let mut sim = ServingSim::with_options(config, EngineCosts::default(), false);
        let arrivals = (0..n_requests).map(|i| StreamArrival {
            at_ns: i * 50_000_000, // 20 rps
            class: ReqClass::Normal,
            prompt_tokens: 600,
            max_new_tokens: 4,
            content_seed: i,
            tag: 0,
        });
        alloc::reset_peak_live();
        let base = alloc::live_bytes();
        let mut harvested = 0u64;
        let stats = sim.run_streaming(arrivals, 30.0, |_o| harvested += 1);
        assert_eq!(stats.submitted, n_requests);
        assert_eq!(harvested, n_requests, "every request reported exactly once");
        alloc::peak_live_bytes() - base
    };
    let small = run(300);
    let large = run(3_000);
    assert!(
        large < small * 2 + (256 << 10),
        "peak live grew with request count: {small} → {large} bytes"
    );
}
