//! Resilience-layer acceptance tests: deterministic fault replay,
//! shed/abort behavior under CPU starvation vs. ample cores, faulted
//! trace JSON round-trips, and `--jobs` byte-identity for scenarios
//! that arm admission control and inject faults.

use cpuslow::config::{ModelSpec, RunConfig, ServeConfig, SystemSpec};
use cpuslow::experiments::serve_sweep;
use cpuslow::sweep::{seeded_cells, Sweep};
use cpuslow::workload::scenario::{run_trace, Scenario, ScenarioReport, Trace};

fn cfg(cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, cores)
}

fn assert_reports_equal(a: &ScenarioReport, b: &ScenarioReport, what: &str) {
    assert_eq!(a.issued, b.issued, "{what}: issued");
    assert_eq!(a.timeouts, b.timeouts, "{what}: timeouts");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.rejected, b.rejected, "{what}: rejected");
    assert_eq!(a.aborted, b.aborted, "{what}: aborted");
    assert_eq!(a.retries, b.retries, "{what}: retries");
    assert_eq!(a.ttft_p50_s, b.ttft_p50_s, "{what}: p50");
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s, "{what}: p99");
    assert_eq!(a.steps_completed, b.steps_completed, "{what}: steps");
}

/// Same seed + same FaultSpecs ⇒ byte-identical replay. The fault draws
/// are pure hashes of (window index, event identity), never a mutable
/// RNG, so replaying a faulted trace reproduces every stall and spike.
#[test]
fn fault_replay_is_deterministic() {
    for name in ["replica-failure", "degraded-tokenizer"] {
        let trace = Scenario::by_name(name).unwrap().generate(7);
        assert!(!trace.faults.is_empty(), "{name} carries fault specs");
        let a = run_trace(cfg(8), &trace);
        let b = run_trace(cfg(8), &trace);
        assert_reports_equal(&a, &b, name);
        assert!(a.issued > 0);
    }
}

/// The injected tokenizer degradation must actually bite: the same
/// trace with its faults stripped completes strictly faster.
#[test]
fn tokenizer_fault_visibly_degrades_service() {
    let trace = Scenario::by_name("degraded-tokenizer").unwrap().generate(5);
    let mut clean = trace.clone();
    clean.faults.clear();
    let faulted = run_trace(cfg(16), &trace);
    let healthy = run_trace(cfg(16), &clean);
    assert_eq!(faulted.issued, healthy.issued);
    let fp50 = faulted.ttft_p50_s.expect("faulted run still serves");
    let hp50 = healthy.ttft_p50_s.expect("healthy run serves");
    assert!(
        fp50 > hp50,
        "400ms stalls at p=0.6 must raise on-time TTFT p50: {fp50:.3} vs {hp50:.3}"
    );
}

/// Flash-crowd on starved cores sheds/aborts strictly more than on
/// ample cores, and the oversized class is rejected at admission on
/// both (a permanent condition, independent of provisioning).
#[test]
fn starved_cores_shed_and_abort_strictly_more() {
    // 2× the catalog rates guarantees the 5-core tokenizer saturates
    // through the burst phases while 48 cores stay comfortably ahead.
    let trace = Scenario::by_name("flash-crowd").unwrap().scaled(2.0).generate(3);
    let starved = run_trace(cfg(5), &trace);
    let ample = run_trace(cfg(48), &trace);
    assert_eq!(starved.issued, ample.issued);
    assert!(starved.shed > 0, "starved run must shed under overload");
    assert!(
        starved.shed + starved.aborted > ample.shed + ample.aborted,
        "starved {}+{} vs ample {}+{}",
        starved.shed,
        starved.aborted,
        ample.shed,
        ample.aborted
    );
    // Never-fit prompts (600k tokens > 524k KV capacity) reject on both.
    assert!(starved.rejected > 0);
    assert_eq!(starved.rejected, ample.rejected);
    let oversized = starved
        .per_class
        .iter()
        .find(|c| c.name == "oversized")
        .expect("flash-crowd has an oversized class");
    assert_eq!(oversized.rejected, oversized.issued, "every oversized rejects");
    // Shed requests re-enter via client-side retry.
    assert!(starved.retries > 0, "shed requests must be retried");
    // Ample provisioning still completes work on time.
    assert!(ample.issued - ample.timeouts > 0, "ample completes on time");
}

/// Faulted traces survive the JSON round-trip byte-identically — the
/// resilience block and fault list serialize with the trace, so a
/// dumped faulted run replays exactly.
#[test]
fn faulted_trace_json_roundtrip() {
    for name in ["flash-crowd", "replica-failure", "degraded-tokenizer"] {
        let trace = Scenario::by_name(name).unwrap().with_duration(6.0).generate(5);
        let dump = trace.to_json().to_string_pretty();
        let parsed = cpuslow::util::json::parse(&dump).unwrap();
        let back = Trace::from_json(&parsed).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(back, trace, "{name}: round-trip equality");
        assert_eq!(back.to_json().to_string_pretty(), dump, "{name}: byte-stable");
    }
}

fn sweep_output(jobs: usize) -> String {
    let scenarios = vec![
        Scenario::by_name("flash-crowd").unwrap().with_duration(6.0),
        Scenario::by_name("replica-failure").unwrap().with_duration(6.0),
    ];
    let specs = serve_sweep::grid(
        &scenarios,
        &SystemSpec::blackwell(),
        &ModelSpec::llama31_8b(),
        &ServeConfig::default(),
        &[4],
        Some(&[5, 16]),
        &[1],
        &[],
    );
    let cells = seeded_cells(0, specs);
    let results = Sweep::new("test", jobs)
        .quiet(true)
        .run(cells, serve_sweep::run_cell);
    let table = serve_sweep::render_cells("resilience determinism", &results).render();
    let json = serve_sweep::cells_to_json(&results).to_string_pretty();
    table + &json
}

/// Acceptance criterion: resilience gates, retry jitter, and fault
/// injection stay byte-identical across `--jobs` values — retry streams
/// key off arrival-order identity and fault draws off pure hashes, so
/// worker schedule cannot leak into outcomes.
#[test]
fn faulted_sweep_jobs_byte_identical() {
    let serial = sweep_output(1);
    let parallel = sweep_output(3);
    assert!(serial.contains("shed rate"), "sweep table carries shed column");
    assert_eq!(serial, parallel);
}

/// KV-page conservation across the whole catalog: after horizon cleanup
/// every page — including pages held by requests that were shed,
/// aborted, failed over, or caught mid-handoff in the disaggregated
/// scenarios — must be back in the free pool.
#[test]
fn no_catalog_scenario_leaks_kv_pages() {
    // The loop walks the whole catalog, so it must include the
    // overload-survival entries — they are the only ones that exercise
    // the recompute-preemption (KvCache::evict) cleanup path.
    let names: Vec<String> = Scenario::catalog().into_iter().map(|s| s.name).collect();
    for required in ["priority-flash-crowd", "kv-thrash"] {
        assert!(
            names.iter().any(|n| n == required),
            "catalog must carry {required} so the leak sweep covers preemption"
        );
    }
    for scenario in Scenario::catalog() {
        let name = scenario.name.clone();
        let trace = scenario.with_duration(6.0).generate(9);
        let report = run_trace(cfg(8), &trace);
        assert!(report.issued > 0, "{name} issued nothing");
        cpuslow::testkit::assert_no_kv_leak(&report);
    }
}
