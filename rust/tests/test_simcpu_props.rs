//! Simulator invariants after the event-loop fast-path refactor
//! (gate→core poll index, per-gate waiter heaps, idle-core free list):
//!
//! * conservation — `busy_core_ns ≤ cores × elapsed`, task CPU ≤ busy;
//! * golden wait accounting — exact, hand-derived `wait_ns` totals for
//!   fixed round-robin scenarios (unchanged from the pre-refactor
//!   scheduler semantics);
//! * wake-order parity — blocked waiters wake in block order (the old
//!   scan's FIFO), not heap-pop order;
//! * bitwise determinism of a seeded random workload.

use cpuslow::simcpu::script::Script;
use cpuslow::simcpu::{Sim, SimParams, TaskId};
use cpuslow::util::rng::Rng;
use std::cell::RefCell;
use std::rc::Rc;

fn params(cores: usize, context_switch_ns: u64) -> SimParams {
    SimParams {
        cores,
        context_switch_ns,
        timeslice_ns: 1_000_000,
        poll_quantum_ns: 1_000,
        trace_bucket_ns: None,
    }
}

/// A seeded mixed workload: compute/sleep chains, gate blockers, and
/// busy-pollers, with enough signals that every waiter is released.
fn random_workload(seed: u64, cores: usize) -> (Sim, Vec<TaskId>) {
    let mut rng = Rng::new(seed);
    let mut sim = Sim::new(params(cores, 2_000));
    let gate = sim.new_gate();
    let mut ids = Vec::new();
    for i in 0..24 {
        let compute = 500_000 + rng.below(8_000_000);
        let sleep = 1 + rng.below(3_000_000);
        let target = 1 + rng.below(50);
        let script = match i % 3 {
            0 => Script::new()
                .compute(compute)
                .sleep(sleep)
                .compute(compute / 2),
            1 => Script::new()
                .compute(compute / 4)
                .block(gate, target)
                .compute(compute),
            _ => Script::new().busy_poll(gate, target).compute(compute / 3),
        };
        ids.push(sim.spawn("mix", script));
    }
    // 60 signals cover the max target of 50
    for t in 0..60u64 {
        sim.call_at(t * 500_000, move |sim| sim.signal(gate, 1));
    }
    (sim, ids)
}

#[test]
fn busy_time_bounded_by_capacity() {
    for seed in [1u64, 7, 42] {
        for cores in [1usize, 3, 8] {
            let (mut sim, ids) = random_workload(seed, cores);
            sim.run();
            sim.flush_traces();
            let elapsed = sim.now_ns();
            let busy = sim.stats().busy_core_ns;
            assert!(
                busy <= cores as u64 * elapsed,
                "seed {seed}, {cores} cores: busy {busy} > {cores} × {elapsed}"
            );
            let task_cpu: u64 = ids.iter().map(|&id| sim.task_stats(id).cpu_ns).sum();
            assert!(
                task_cpu <= busy,
                "task cpu {task_cpu} exceeds busy core time {busy}"
            );
            for &id in &ids {
                let st = sim.task_stats(id);
                assert!(st.finished, "task {id} did not finish (seed {seed})");
                assert!(st.poll_cpu_ns <= st.cpu_ns);
            }
        }
    }
}

#[test]
fn fixed_seed_reproduces_bitwise() {
    let run = |seed: u64| {
        let (mut sim, ids) = random_workload(seed, 4);
        sim.run();
        let per_task: Vec<(u64, u64, u64, u64)> = ids
            .iter()
            .map(|&id| {
                let s = sim.task_stats(id);
                (s.cpu_ns, s.poll_cpu_ns, s.wait_ns, s.switches)
            })
            .collect();
        (
            sim.now_ns(),
            sim.stats().context_switches,
            sim.stats().events_processed,
            per_task,
        )
    };
    assert_eq!(run(9), run(9));
    assert_eq!(run(1234), run(1234));
}

/// Two 10 ms tasks round-robining on one core (1 ms slices, free
/// switches): T0 waits during 9 of T1's slices, T1 during 10 of T0's.
/// These exact totals are the pre-refactor scheduler's values.
#[test]
fn golden_wait_two_tasks_one_core() {
    let mut sim = Sim::new(params(1, 0));
    let a = sim.spawn("t", Script::new().compute(10_000_000));
    let b = sim.spawn("t", Script::new().compute(10_000_000));
    let end = sim.run();
    assert_eq!(end, 20_000_000, "makespan");
    let sa = sim.task_stats(a);
    let sb = sim.task_stats(b);
    assert_eq!(sa.cpu_ns, 10_000_000);
    assert_eq!(sb.cpu_ns, 10_000_000);
    assert_eq!(sa.wait_ns, 9_000_000, "first task waits 9 slices");
    assert_eq!(sb.wait_ns, 10_000_000, "second task waits 10 slices");
    assert_eq!(sa.wait_ns + sb.wait_ns, 19_000_000);
}

/// Eight 10 ms tasks on two cores: fully busy for 40 ms; the waiting
/// integral is 6 waiters × 36 ms + (6 + 4 + 2) ms over the final
/// staggered round = 228 ms total.
#[test]
fn golden_wait_eight_tasks_two_cores() {
    let mut sim = Sim::new(params(2, 0));
    let ids: Vec<TaskId> = (0..8)
        .map(|_| sim.spawn("t", Script::new().compute(10_000_000)))
        .collect();
    let end = sim.run();
    assert_eq!(end, 40_000_000, "makespan");
    sim.flush_traces();
    assert_eq!(sim.stats().busy_core_ns, 80_000_000, "cores never idle");
    let total_wait: u64 = ids.iter().map(|&id| sim.task_stats(id).wait_ns).sum();
    assert_eq!(total_wait, 228_000_000);
}

#[test]
fn equal_target_blockers_wake_in_block_order() {
    let mut sim = Sim::new(params(1, 0));
    let gate = sim.new_gate();
    let order: Rc<RefCell<Vec<usize>>> = Rc::new(RefCell::new(Vec::new()));
    for i in 0..3 {
        let order = Rc::clone(&order);
        sim.spawn(
            "w",
            Script::new()
                .block(gate, 1)
                .compute(1_000_000)
                .effect(move |_| order.borrow_mut().push(i)),
        );
    }
    sim.call_at(1_000_000, move |sim| sim.signal(gate, 1));
    sim.run();
    assert_eq!(*order.borrow(), vec![0, 1, 2], "FIFO wake among equal targets");
}

#[test]
fn mixed_target_blockers_released_by_one_signal_wake_in_block_order() {
    // Targets 3, 1, 2 — one big signal satisfies all three at once; the
    // pre-refactor scan woke them in block order, so must the heap.
    let mut sim = Sim::new(params(1, 0));
    let gate = sim.new_gate();
    let order: Rc<RefCell<Vec<usize>>> = Rc::new(RefCell::new(Vec::new()));
    for (i, target) in [3u64, 1, 2].into_iter().enumerate() {
        let order = Rc::clone(&order);
        sim.spawn(
            "w",
            Script::new()
                .block(gate, target)
                .compute(1_000_000)
                .effect(move |_| order.borrow_mut().push(i)),
        );
    }
    sim.call_at(2_000_000, move |sim| sim.signal(gate, 3));
    sim.run();
    assert_eq!(*order.borrow(), vec![0, 1, 2]);
}

#[test]
fn staged_signals_release_by_target() {
    // Targets 3, 1, 2 with +1 signals at 1/2/3 ms: wake times must
    // follow targets, exercising the partial-pop path of the heap.
    let mut sim = Sim::new(params(3, 0));
    let gate = sim.new_gate();
    let woke: Rc<RefCell<Vec<(usize, u64)>>> = Rc::new(RefCell::new(Vec::new()));
    for (i, target) in [3u64, 1, 2].into_iter().enumerate() {
        let woke = Rc::clone(&woke);
        sim.spawn(
            "w",
            Script::new()
                .block(gate, target)
                .effect(move |ctx| woke.borrow_mut().push((i, ctx.now_ns()))),
        );
    }
    for t in 1..=3u64 {
        sim.call_at(t * 1_000_000, move |sim| sim.signal(gate, 1));
    }
    sim.run();
    let woke = woke.borrow();
    assert_eq!(*woke, vec![(1, 1_000_000), (2, 2_000_000), (0, 3_000_000)]);
}

#[test]
fn event_counter_counts_and_poll_index_survives_churn() {
    // A poller that re-polls across preemption (slice renewals and
    // vacates) while hogs churn the core: the gate→core registration
    // must stay correct through stale entries.
    let mut sim = Sim::new(params(1, 0));
    let gate = sim.new_gate();
    let noticed: Rc<RefCell<Option<u64>>> = Rc::new(RefCell::new(None));
    {
        let noticed = Rc::clone(&noticed);
        sim.spawn(
            "poller",
            Script::new()
                .busy_poll(gate, 1)
                .effect(move |ctx| *noticed.borrow_mut() = Some(ctx.now_ns())),
        );
    }
    sim.spawn("hog", Script::new().compute(10_000_000));
    sim.call_at(4_000_000, move |sim| sim.signal(gate, 1));
    sim.run();
    let t = noticed.borrow().expect("poller completed");
    assert!(t >= 4_000_000, "cannot notice before the signal: {t}");
    assert!(sim.stats().events_processed > 0);
}
