//! Golden-trace equivalence for the timing-wheel event core.
//!
//! The simulator's dispatch order is part of its contract: golden wait
//! totals, wake-order parity, and bitwise sweep determinism all depend
//! on events firing in exact `(time, insertion seq)` order. These tests
//! replay mixed workloads — spawn / signal / call_at / busy-poll /
//! block / sleep / yield — on the timing wheel **and** on the retained
//! pre-wheel reference heap (`Sim::new_with_reference_queue`), then
//! assert the processed-event traces, final clocks, `SimStats`, and
//! per-task stats are identical. The heap run *is* the recorded
//! baseline: it is the exact implementation the wheel replaced.

use cpuslow::simcpu::script::{Instr, Script};
use cpuslow::simcpu::{Op, Sim, SimParams, TaskCtx, TaskId, TraceEvent};
use cpuslow::util::rng::Rng;

fn params(cores: usize) -> SimParams {
    SimParams {
        cores,
        context_switch_ns: 2_000,
        timeslice_ns: 1_000_000,
        poll_quantum_ns: 1_000,
        trace_bucket_ns: None,
    }
}

/// Everything observable about a finished run.
struct RunRecord {
    trace: Vec<TraceEvent>,
    end_ns: u64,
    context_switches: u64,
    events_processed: u64,
    busy_core_ns: u64,
    per_task: Vec<(u64, u64, u64, u64, bool)>,
}

fn record(mut sim: Sim, ids: &[TaskId]) -> RunRecord {
    let end_ns = sim.run();
    sim.flush_traces();
    let per_task = ids
        .iter()
        .map(|&id| {
            let s = sim.task_stats(id);
            (s.cpu_ns, s.poll_cpu_ns, s.wait_ns, s.switches, s.finished)
        })
        .collect();
    RunRecord {
        trace: sim.take_event_trace(),
        end_ns,
        context_switches: sim.stats().context_switches,
        events_processed: sim.stats().events_processed,
        busy_core_ns: sim.stats().busy_core_ns,
        per_task,
    }
}

fn assert_equivalent(wheel: RunRecord, heap: RunRecord, label: &str) {
    assert!(!wheel.trace.is_empty(), "{label}: empty trace");
    // Compare the traces event by event so a divergence points at the
    // first differing (time, kind, a, b) tuple rather than a wall of
    // output.
    for (i, (w, h)) in wheel.trace.iter().zip(&heap.trace).enumerate() {
        assert_eq!(w, h, "{label}: traces diverge at event {i}");
    }
    assert_eq!(wheel.trace.len(), heap.trace.len(), "{label}: trace length");
    assert_eq!(wheel.end_ns, heap.end_ns, "{label}: end time");
    assert_eq!(
        wheel.context_switches, heap.context_switches,
        "{label}: context switches"
    );
    assert_eq!(
        wheel.events_processed, heap.events_processed,
        "{label}: events processed"
    );
    assert_eq!(wheel.busy_core_ns, heap.busy_core_ns, "{label}: busy ns");
    assert_eq!(wheel.per_task, heap.per_task, "{label}: per-task stats");
}

/// A seeded workload exercising every op and every deferred effect:
/// compute/sleep/yield chains, gate blockers with mixed targets,
/// busy-pollers, program-driven spawns, and program-driven `call_at`
/// callbacks that signal gates later.
fn mixed_workload(sim: &mut Sim, seed: u64) -> Vec<TaskId> {
    sim.enable_event_trace();
    let mut rng = Rng::new(seed);
    let gate = sim.new_gate();
    let late_gate = sim.new_gate();
    let mut ids = Vec::new();
    for i in 0..28 {
        let compute = 200_000 + rng.below(5_000_000);
        let sleep = 1 + rng.below(2_500_000);
        let target = 1 + rng.below(40);
        let script = match i % 5 {
            0 => Script::new()
                .compute(compute)
                .sleep(sleep)
                .compute(compute / 2),
            1 => Script::new()
                .compute(compute / 4)
                .block(gate, target)
                .compute(compute),
            2 => Script::new().busy_poll(gate, target).compute(compute / 3),
            3 => Script::new()
                .compute(compute / 8)
                .then(move |ctx| {
                    // dynamic continuation: schedule a future signal and
                    // spawn a child that blocks on it
                    let t = ctx.now_ns() + 3_000_000;
                    ctx.call_at(t, move |sim| sim.signal(late_gate, 1));
                    ctx.spawn(
                        "child",
                        Script::new().block(late_gate, 1).compute(100_000),
                    );
                    vec![Instr::compute(50_000)]
                })
                .sleep(sleep / 2),
            _ => Script::new()
                .compute(compute / 6)
                .yield_now()
                .block(late_gate, 1)
                .compute(compute / 5),
        };
        ids.push(sim.spawn("mix", script));
    }
    // weighted latency-critical task, exercising vruntime divergence
    ids.push(sim.spawn_weighted(
        "prio",
        4,
        Script::new().compute(2_000_000).sleep(500_000).compute(750_000),
    ));
    // enough staggered signals to release every gate waiter
    for t in 0..50u64 {
        sim.call_at(t * 400_000, move |sim| sim.signal(gate, 1));
    }
    sim.call_at(30_000_000, move |sim| sim.signal(late_gate, 1));
    ids
}

#[test]
fn wheel_trace_matches_heap_baseline() {
    for seed in [5u64, 77, 4242] {
        for cores in [1usize, 4] {
            let mut a = Sim::new(params(cores));
            let ids_a = mixed_workload(&mut a, seed);
            let mut b = Sim::new_with_reference_queue(params(cores));
            let ids_b = mixed_workload(&mut b, seed);
            assert_eq!(ids_a, ids_b);
            assert_equivalent(
                record(a, &ids_a),
                record(b, &ids_b),
                &format!("seed {seed}, {cores} cores"),
            );
        }
    }
}

/// `run_until` boundaries must not perturb the trace: stepping the clock
/// in small increments (forcing many Beyond-the-limit returns and
/// cursor parks) yields the same event sequence as one uninterrupted
/// run, on both queues.
#[test]
fn stepped_run_until_is_transparent() {
    let build = |reference: bool| {
        let mut sim = if reference {
            Sim::new_with_reference_queue(params(2))
        } else {
            Sim::new(params(2))
        };
        let ids = mixed_workload(&mut sim, 99);
        (sim, ids)
    };
    // uninterrupted wheel run as the reference trace
    let (whole, ids) = build(false);
    let whole_rec = record(whole, &ids);
    for reference in [false, true] {
        let (mut sim, ids) = build(reference);
        let mut limit = 0u64;
        while sim.now_ns() < whole_rec.end_ns {
            limit += 1_700_000; // deliberately not a divisor of anything
            sim.run_until(limit);
        }
        let end = sim.run();
        sim.flush_traces();
        assert_eq!(end, whole_rec.end_ns);
        let rec = RunRecord {
            trace: sim.take_event_trace(),
            end_ns: end,
            context_switches: sim.stats().context_switches,
            events_processed: sim.stats().events_processed,
            busy_core_ns: sim.stats().busy_core_ns,
            per_task: ids
                .iter()
                .map(|&id| {
                    let s = sim.task_stats(id);
                    (s.cpu_ns, s.poll_cpu_ns, s.wait_ns, s.switches, s.finished)
                })
                .collect(),
        };
        assert_equivalent(
            rec,
            RunRecord {
                trace: whole_rec.trace.clone(),
                end_ns: whole_rec.end_ns,
                context_switches: whole_rec.context_switches,
                events_processed: whole_rec.events_processed,
                busy_core_ns: whole_rec.busy_core_ns,
                per_task: whole_rec.per_task.clone(),
            },
            &format!("stepped (reference={reference})"),
        );
    }
}

/// Raw-`Program` (non-Script) state machines driving deferred spawns and
/// signals mid-dispatch — the re-entrant path through `apply_deferred`.
#[test]
fn reentrant_spawn_signal_parity() {
    let build = |reference: bool| {
        let mut sim = if reference {
            Sim::new_with_reference_queue(params(2))
        } else {
            Sim::new(params(2))
        };
        sim.enable_event_trace();
        let gate = sim.new_gate();
        let mut ids = Vec::new();
        for i in 0..6u64 {
            let mut state = 0u64;
            ids.push(sim.spawn("chain", move |ctx: &mut TaskCtx| {
                state += 1;
                match state {
                    1 => Op::Compute { ns: 300_000 + i * 70_000 },
                    2 => {
                        // spawn a grandchild and signal from inside step
                        ctx.spawn("grand", Script::new().compute(90_000));
                        ctx.signal(gate, 1);
                        Op::Block { gate, target: 6 }
                    }
                    _ => Op::Done,
                }
            }));
        }
        sim.run();
        for &id in &ids {
            assert!(sim.task_finished(id), "task {id} deadlocked");
        }
        (sim.take_event_trace(), sim.now_ns(), sim.stats().clone())
    };
    let (tw, nw, sw) = build(false);
    let (th, nh, sh) = build(true);
    assert!(!tw.is_empty());
    assert_eq!(tw, th);
    assert_eq!(nw, nh);
    assert_eq!(sw, sh);
}
