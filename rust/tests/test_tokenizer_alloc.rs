//! Allocation-behavior acceptance tests for the tokenizer hot path,
//! in the same style as `test_alloc.rs` for the serving engine: the
//! counting global allocator proves that warmed encode calls never
//! touch the allocator, and that batch dispatch has a bounded,
//! non-growing caller-side allocation profile.
//!
//! Counters are per-thread, so worker-side scratch (thread-local merge
//! scratch, per-chunk output buffers) is exercised but measured only
//! where it matters: the steady-state claim is about repeat calls, and
//! worker scratch is reused across them by construction.

use cpuslow::testkit::alloc::{self, CountingAlloc};
use cpuslow::tokenizer::{
    corpus::Lexicon, encode_uncached_into, train, BatchTokenizer, Encoder, Merge, Vocab,
};
use cpuslow::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn trained() -> (cpuslow::tokenizer::Vocab, Lexicon, Rng) {
    let lex = Lexicon::generate(0x7A, 400);
    let mut rng = Rng::new(0x7B);
    let corpus = lex.sample_corpus(&mut rng, 8, 2_048);
    (train(&corpus, 400), lex, rng)
}

#[test]
fn warmed_encoder_encode_into_allocates_nothing() {
    let (vocab, lex, mut rng) = trained();
    let text = lex.sample_text(&mut rng, 8_192);
    let mut enc = Encoder::new(&vocab);
    let mut out = Vec::new();
    // Warmup: populate the word cache + arena, grow the thread-local
    // merge scratch, and size the output buffer.
    for _ in 0..3 {
        out.clear();
        enc.encode_into(&text, &mut out);
    }
    let expected = out.clone();
    let before = alloc::counters();
    for _ in 0..10 {
        out.clear();
        enc.encode_into(&text, &mut out);
    }
    let after = alloc::counters();
    assert_eq!(
        after.allocs - before.allocs,
        0,
        "warmed encode_into allocated ({} allocs / {} bytes over 10 calls)",
        after.allocs - before.allocs,
        after.alloc_bytes - before.alloc_bytes,
    );
    assert_eq!(out, expected, "zero-alloc path changed the output");
}

#[test]
fn warmed_uncached_encode_into_allocates_nothing() {
    // Even without the word cache, the heap-merge loop itself is
    // allocation-free once the merge scratch has grown to the largest
    // word: this is the 64 KB bench scenario's steady state.
    let (vocab, lex, mut rng) = trained();
    let text = lex.sample_text(&mut rng, 16_384);
    let mut out = Vec::new();
    for _ in 0..2 {
        out.clear();
        encode_uncached_into(&vocab, &text, &mut out);
    }
    let expected = out.clone();
    let before = alloc::counters();
    for _ in 0..5 {
        out.clear();
        encode_uncached_into(&vocab, &text, &mut out);
    }
    let after = alloc::counters();
    assert_eq!(
        after.allocs - before.allocs,
        0,
        "warmed encode_uncached_into allocated ({} allocs over 5 calls)",
        after.allocs - before.allocs,
    );
    assert_eq!(out, expected);
}

#[test]
fn encoder_encode_allocates_only_the_output_buffer() {
    // The by-value API cannot be zero-alloc (it returns a fresh Vec);
    // pin it to "output buffer only". Handcrafted merges make the token
    // count exact: "the" and " the" each collapse to one token, so the
    // len/3 pre-size always fits and never regrows.
    // Space-leading merges first so " the" fully collapses before the
    // bare (t,h) path could strand a lone leading-space token.
    let mut v = Vocab::bytes_only();
    let sp_t = v.push_merge(Merge {
        left: b' ' as u32,
        right: b't' as u32,
    });
    let sp_th = v.push_merge(Merge {
        left: sp_t,
        right: b'h' as u32,
    });
    v.push_merge(Merge {
        left: sp_th,
        right: b'e' as u32,
    });
    let th = v.push_merge(Merge {
        left: b't' as u32,
        right: b'h' as u32,
    });
    v.push_merge(Merge {
        left: th,
        right: b'e' as u32,
    });
    let text = "the the the the the the"; // 23 bytes → pre-size 7 ≥ 6 tokens
    let mut enc = Encoder::new(&v);
    let warm = enc.encode(text);
    assert_eq!(warm.len(), 6);
    let before = alloc::counters();
    let ids = enc.encode(text);
    let after = alloc::counters();
    assert_eq!(
        after.allocs - before.allocs,
        1,
        "warmed encode should allocate exactly its output Vec"
    );
    assert_eq!(ids, warm);
}

#[test]
fn encode_batch_steady_state_allocations_bounded() {
    // Caller-side allocations for a batch dispatch must be a small flat
    // constant (job scaffolding + result slots), not O(tokens) and not
    // growing call over call. Worker-side buffers are counted on the
    // worker threads; what this pins is that repeat batches don't leak
    // or accumulate on the submitting thread.
    let (vocab, lex, mut rng) = trained();
    let tok = BatchTokenizer::new(vocab, 2);
    let texts: Vec<String> = (0..8).map(|_| lex.sample_text(&mut rng, 2_048)).collect();
    let run = |texts: &[String]| -> u64 {
        let before = alloc::counters();
        let out = tok.encode_batch_refs(texts);
        let after = alloc::counters();
        assert_eq!(out.len(), texts.len());
        after.allocs - before.allocs
    };
    let first = run(&texts);
    let warm2 = run(&texts);
    let warm3 = run(&texts);
    let warm4 = run(&texts);
    assert!(
        warm3 <= warm2 && warm4 <= warm3,
        "caller-side allocs grew across batches: {first} → {warm2} → {warm3} → {warm4}"
    );
    assert!(
        warm4 < 64,
        "caller-side allocs per batch should be a small constant: {warm4}"
    );
}
