//! Scenario-engine acceptance tests: golden per-seed sequences, JSON
//! trace round-trips, serve-sweep determinism across `--jobs`, and the
//! paper's core sanity property (CPU-starved cores time out strictly
//! more than ample cores under the same offered load).

use cpuslow::config::{ModelSpec, RunConfig, ServeConfig, SystemSpec};
use cpuslow::experiments::serve_sweep;
use cpuslow::sweep::seeded_cells;
use cpuslow::sweep::Sweep;
use cpuslow::workload::scenario::{
    class_streams, run_trace, ArrivalSpec, ClassSpec, LenDist, LengthSpec, Scenario, Trace,
    TRACE_SEED_MASK,
};

fn single_class_scenario(
    name: &str,
    arrivals: ArrivalSpec,
    prompt: LenDist,
    slo_ttft_s: f64,
    duration_s: f64,
    shared_prompt: bool,
) -> Scenario {
    Scenario {
        name: name.into(),
        description: "test fixture".into(),
        paper_section: "-".into(),
        duration_s,
        classes: vec![ClassSpec {
            name: "only".into(),
            arrivals,
            lengths: LengthSpec {
                prompt,
                output: LenDist::Fixed { tokens: 4 },
            },
            slo_ttft_s,
            shared_prompt,
        }],
        resilience: None,
        faults: vec![],
    }
}

/// Golden per-class stream derivation, cross-checked against an
/// independent SplitMix64 implementation (Python, exact 64-bit
/// arithmetic). Locks the (seed, class index) → stream mapping: any
/// change to `class_streams` silently re-rolls every committed trace.
#[test]
fn golden_class_stream_seeds() {
    assert_eq!(
        class_streams(42, 0),
        (0x4D9B_3F1E_C9CF_6B1B, 0x78C2_D7CD_08DB_B11F, 0x4A4D_8313_99CC_FC4E)
    );
    assert_eq!(
        class_streams(42, 1),
        (0x7EB3_B394_AC9E_FC29, 0xA992_255A_56FD_15F3, 0xD95F_51AC_5959_24F4)
    );
    assert_eq!(
        class_streams(42, 2),
        (0x1DB2_233E_B3BC_AEB3, 0x406D_6B3C_5D3E_D022, 0x7CB9_4DCC_BAC2_3F41)
    );
    assert_eq!(
        class_streams(7, 0),
        (0x64BF_61B5_12FF_ABE7, 0x365D_612F_A018_E7CF, 0x0D7C_74CE_CEAE_9809)
    );
}

/// Golden arrival/length/content sequence for a fully deterministic
/// scenario at seed 42: periodic arrivals are exact, fixed lengths are
/// exact, and content seeds follow the masked stream base.
#[test]
fn golden_periodic_trace_at_seed_42() {
    let s = single_class_scenario(
        "golden",
        ArrivalSpec::Periodic { rps: 2.0 },
        LenDist::Fixed { tokens: 100 },
        30.0,
        2.0,
        false,
    );
    let trace = s.generate(42);
    let content_base = 0x4A4D_8313_99CC_FC4E_u64 & TRACE_SEED_MASK;
    assert_eq!(trace.requests.len(), 4);
    for (k, r) in trace.requests.iter().enumerate() {
        assert_eq!(r.at_ns, k as u64 * 500_000_000);
        assert_eq!(r.prompt_tokens, 100);
        assert_eq!(r.output_tokens, 4);
        assert_eq!(r.class_idx, 0);
        assert_eq!(
            r.content_seed,
            content_base.wrapping_add(k as u64 + 1) & TRACE_SEED_MASK
        );
    }
}

#[test]
fn trace_json_roundtrip_is_byte_identical() {
    let scenario = Scenario::by_name("multi-tenant").unwrap().with_duration(8.0);
    let trace = scenario.generate(3);
    assert!(!trace.requests.is_empty());
    let json_a = trace.to_json().to_string_pretty();
    let back = Trace::from_json(&trace.to_json()).expect("parse own dump");
    assert_eq!(back, trace);
    let json_b = back.to_json().to_string_pretty();
    assert_eq!(json_a, json_b);
    // Re-parse the serialized text end to end (file-shaped path).
    let reparsed = cpuslow::util::json::parse(&json_a).unwrap();
    assert_eq!(Trace::from_json(&reparsed).unwrap(), trace);
}

#[test]
fn run_trace_is_deterministic() {
    let scenario = single_class_scenario(
        "det",
        ArrivalSpec::Poisson { rps: 4.0 },
        LenDist::Lognormal {
            mean: 2_000.0,
            sigma: 0.8,
            min: 64,
        },
        30.0,
        4.0,
        false,
    );
    let trace = scenario.generate(11);
    let cfg = || RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, 8);
    let a = run_trace(cfg(), &trace);
    let b = run_trace(cfg(), &trace);
    assert_eq!(a.issued, b.issued);
    assert_eq!(a.timeouts, b.timeouts);
    assert_eq!(a.ttft_p50_s, b.ttft_p50_s);
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s);
    assert_eq!(a.steps_completed, b.steps_completed);
    assert!(a.issued > 0);
}

fn sweep_output(jobs: usize) -> String {
    let scenario = single_class_scenario(
        "tiny",
        ArrivalSpec::Poisson { rps: 4.0 },
        LenDist::Lognormal {
            mean: 2_000.0,
            sigma: 0.8,
            min: 64,
        },
        30.0,
        5.0,
        false,
    );
    let specs = serve_sweep::grid(
        &[scenario],
        &SystemSpec::blackwell(),
        &ModelSpec::llama31_8b(),
        &ServeConfig::default(),
        &[4],
        Some(&[5, 16]),
        &[1],
        &[],
    );
    let cells = seeded_cells(0, specs);
    let results = Sweep::new("test", jobs)
        .quiet(true)
        .run(cells, serve_sweep::run_cell);
    let table = serve_sweep::render_cells("determinism check", &results).render();
    let json = serve_sweep::cells_to_json(&results).to_string_pretty();
    table + &json
}

/// Acceptance criterion: `serve-sweep --jobs N` output is byte-identical
/// to `--jobs 1` (tables and JSON), because cell seeds derive from the
/// cell index and never from the worker schedule.
#[test]
fn serve_sweep_jobs_byte_identical() {
    let serial = sweep_output(1);
    let parallel = sweep_output(3);
    assert!(!serial.is_empty());
    assert_eq!(serial, parallel);
}

/// The paper's core serving claim as a scenario-engine sanity check:
/// under an offered load whose tokenization demand (~31 core-s/s)
/// exceeds a starved 5-core allocation but fits an ample 48-core one,
/// the starved configuration must time out strictly more.
#[test]
fn starved_cores_time_out_strictly_more() {
    // 24 rps × 90k-token identical prompts ≈ 31 core-s/s of CPU-side
    // tokenization (the shared prompt makes GPU prefill a one-off, as
    // in the paper's attacker construction).
    let scenario = single_class_scenario(
        "saturate",
        ArrivalSpec::Periodic { rps: 24.0 },
        LenDist::Fixed { tokens: 90_000 },
        30.0,
        12.0,
        true,
    );
    let trace = scenario.generate(1);
    let run = |cores: usize| {
        let cfg = RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, cores);
        run_trace(cfg, &trace)
    };
    let starved = run(5);
    let ample = run(48);
    assert_eq!(starved.issued, ample.issued);
    assert!(starved.issued >= 280, "issued {}", starved.issued);
    assert!(
        starved.timeout_rate() > ample.timeout_rate() + 0.2,
        "starved {:.2} vs ample {:.2}",
        starved.timeout_rate(),
        ample.timeout_rate()
    );
    assert!(starved.timeouts > 0);
    assert!(
        ample.timeout_rate() < 0.2,
        "ample rate {:.2}",
        ample.timeout_rate()
    );
    let ample_p50 = ample.ttft_p50_s.expect("ample completes requests");
    assert!(ample_p50 < 15.0, "ample p50 {ample_p50:.2}");
}
