//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run; they self-skip (with a
//! loud message) when artifacts/ is missing so `cargo test` stays green
//! in a fresh checkout.

use cpuslow::runtime::{Manifest, ModelRuntime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("params.bin").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_parses_and_matches_tiny_spec() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let spec = cpuslow::config::ModelSpec::tiny_100m();
    assert_eq!(m.n_layers, spec.n_layers);
    assert_eq!(m.n_heads, spec.n_heads);
    assert_eq!(m.vocab, spec.vocab_size);
    assert!(!m.prefill_buckets.is_empty());
    assert!(m.n_params > 50_000_000);
}

#[test]
fn full_pipeline_prefill_decode() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(dir).expect("load + compile artifacts");

    // prefill a short prompt
    let prompt: Vec<u32> = (1..=40).collect();
    let out = rt.prefill(&prompt).unwrap();
    assert_eq!(out.logits.len(), rt.manifest().vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    assert_eq!(out.bucket, 128);

    // insert into lane 0 and decode three steps
    let mut state = rt.new_decode_state().unwrap();
    rt.insert_lane(&mut state, 0, &out, prompt.len() - 1).unwrap();
    let mut active = vec![false; rt.manifest().decode_batch];
    active[0] = true;
    let mut tok = vec![0i32; rt.manifest().decode_batch];
    tok[0] = *prompt.last().unwrap() as i32;
    let mut seen = Vec::new();
    for _ in 0..3 {
        let logits = rt.decode_step(&mut state, &tok, &active).unwrap();
        assert!(logits[0].iter().all(|x| x.is_finite()));
        let next = ModelRuntime::argmax(&logits[0]);
        seen.push(next);
        tok[0] = next as i32;
    }
    assert_eq!(state.lengths[0] as usize, prompt.len() - 1 + 3);
    assert_eq!(seen.len(), 3);
}

#[test]
fn decode_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(dir).expect("load artifacts");
    let run = || {
        let prompt: Vec<u32> = (5..25).collect();
        let out = rt.prefill(&prompt).unwrap();
        let mut state = rt.new_decode_state().unwrap();
        rt.insert_lane(&mut state, 0, &out, prompt.len() - 1).unwrap();
        let mut active = vec![false; rt.manifest().decode_batch];
        active[0] = true;
        let mut tok = vec![0i32; rt.manifest().decode_batch];
        tok[0] = *prompt.last().unwrap() as i32;
        let mut ids = Vec::new();
        for _ in 0..4 {
            let logits = rt.decode_step(&mut state, &tok, &active).unwrap();
            let next = ModelRuntime::argmax(&logits[0]);
            ids.push(next);
            tok[0] = next as i32;
        }
        ids
    };
    assert_eq!(run(), run());
}

#[test]
fn bucket_selection() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(dir).expect("load artifacts");
    assert_eq!(rt.bucket_for(10), Some(128));
    assert_eq!(rt.bucket_for(128), Some(128));
    assert_eq!(rt.bucket_for(129), Some(256));
    assert_eq!(rt.bucket_for(512), Some(512));
    assert_eq!(rt.bucket_for(513), None);
}
