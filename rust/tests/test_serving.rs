//! Cross-module integration tests: full simulated serving runs and
//! experiment harness smoke checks — the "shape" assertions the paper's
//! figures rest on, executed end-to-end through the public API.

use cpuslow::config::{ModelSpec, RunConfig, SystemSpec};
use cpuslow::engine::{ReqClass, ServingSim};
use cpuslow::workload::{run_attacker_victim, run_baseline, AvSpec};

fn blackwell(cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, cores)
}

#[test]
fn tokenization_fraction_is_substantial_for_long_prompts() {
    // Fig 5's shape: tokenize/TTFT stays a large, roughly stable
    // fraction as SL grows (chunked prefill keeps prefill ~linear).
    let frac_at = |sl: u64| {
        let cfg = RunConfig::new(SystemSpec::h200(), ModelSpec::llama31_8b(), 4, 16);
        let mut sim = ServingSim::new(cfg);
        let id = sim.submit_at(0, ReqClass::Normal, sl, 1);
        sim.run_secs(600.0);
        let o = sim.outcome(id).unwrap();
        let tok = o.tokenize_latency_ns.unwrap() as f64;
        let ttft = o.ttft_ns.unwrap() as f64;
        tok / ttft
    };
    let f16k = frac_at(16_000);
    let f96k = frac_at(96_000);
    assert!(f16k > 0.15, "tokenize fraction at 16k = {f16k:.2}");
    assert!(f96k > 0.15, "tokenize fraction at 96k = {f96k:.2}");
    // does not collapse at long SL (the paper's key Fig-5 observation)
    assert!(f96k > 0.5 * f16k, "fraction must not shrink much: {f16k:.2} → {f96k:.2}");
}

#[test]
fn victim_ttft_ordering_across_core_levels() {
    // Fig 7's shape: TTFT monotone-ish decreasing in cores under load.
    let spec = AvSpec {
        attacker_sl: 80_000,
        rps: 8.0,
        attack_secs: 20.0,
        victim_start_secs: 8.0,
        n_victims: 1,
        max_new_tokens: 8,
        timeout_secs: 90.0,
        ..AvSpec::default()
    };
    let ttft = |cores: usize| {
        run_attacker_victim(blackwell(cores), &spec).mean_ttft_with_timeouts(spec.timeout_secs)
    };
    let t5 = ttft(5);
    let t16 = ttft(16);
    let t32 = ttft(32);
    assert!(t5 > t16 * 1.1, "5 cores {t5:.2}s vs 16 cores {t16:.2}s");
    assert!(t16 >= t32 * 0.8, "16 cores {t16:.2}s vs 32 cores {t32:.2}s");
}

#[test]
fn sequential_victims_grow_under_sustained_overload() {
    // Fig 8's shape: later victims see larger TTFT at scarce cores.
    let spec = AvSpec {
        attacker_sl: 114_000,
        rps: 8.0,
        attack_secs: 120.0,
        victim_start_secs: 5.0,
        n_victims: 3,
        max_new_tokens: 8,
        timeout_secs: 60.0,
        ..AvSpec::default()
    };
    let r = run_attacker_victim(blackwell(5), &spec);
    let vals: Vec<f64> = r
        .victim_ttft_s
        .iter()
        .map(|v| v.unwrap_or(spec.timeout_secs))
        .collect();
    assert!(
        vals.last().unwrap() > vals.first().unwrap(),
        "victim TTFTs should grow: {vals:?}"
    );
}

#[test]
fn cpu_saturation_correlates_with_gpu_underutilization() {
    // Fig 11's shape: scarce-CPU runs show higher CPU util and lower
    // GPU util than abundant-CPU runs of the same workload.
    let spec = AvSpec {
        attacker_sl: 80_000,
        rps: 8.0,
        attack_secs: 15.0,
        victim_start_secs: 5.0,
        n_victims: 1,
        max_new_tokens: 8,
        timeout_secs: 60.0,
        ..AvSpec::default()
    };
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let scarce = run_attacker_victim(blackwell(5), &spec);
    let abundant = run_attacker_victim(blackwell(32), &spec);
    assert!(
        mean(&scarce.cpu_util) > mean(&abundant.cpu_util),
        "scarce CPU busier: {:.2} vs {:.2}",
        mean(&scarce.cpu_util),
        mean(&abundant.cpu_util)
    );
}

#[test]
fn baseline_unaffected_by_core_count() {
    // Without load, 5 vs 32 cores barely matters (the paper's no-load
    // baselines are flat) — sanity check that the simulator does not
    // fabricate contention.
    let spec = AvSpec::default();
    let b5 = run_baseline(blackwell(5), &spec).unwrap();
    let b32 = run_baseline(blackwell(32), &spec).unwrap();
    assert!(b5 < 2.0 * b32, "no-load: {b5:.2}s vs {b32:.2}s");
}

#[test]
fn prefix_cache_absorbs_repeated_prompts() {
    // The attack is CPU-side *because* prefix caching absorbs the GPU
    // prefill of identical prompts: steps complete far faster for the
    // cached stream.
    let mut with_cache = ServingSim::new(blackwell(32));
    for i in 0..6u64 {
        with_cache.submit_with_seed(i * 100_000_000, ReqClass::Attacker, 30_000, 4, 7);
    }
    with_cache.run_secs(120.0);
    let done_cached = with_cache
        .outcomes()
        .iter()
        .filter(|o| o.e2e_ns.is_some())
        .count();

    let mut cfg = blackwell(32);
    cfg.serve.prefix_caching = false;
    let mut without = ServingSim::new(cfg);
    for i in 0..6u64 {
        without.submit_with_seed(i * 100_000_000, ReqClass::Attacker, 30_000, 4, 7);
    }
    without.run_secs(6.0); // same virtual budget as the cached run needed
    let done_uncached = without
        .outcomes()
        .iter()
        .filter(|o| o.e2e_ns.is_some())
        .count();
    assert_eq!(done_cached, 6);
    assert!(
        done_uncached < done_cached,
        "uncached prefill must be slower: {done_uncached} vs {done_cached}"
    );
}

#[test]
fn eight_gpu_configuration_runs() {
    let cfg = RunConfig::new(SystemSpec::h100(), ModelSpec::llama31_8b(), 8, 16);
    let mut sim = ServingSim::new(cfg);
    let id = sim.submit_at(0, ReqClass::Normal, 10_000, 4);
    sim.run_secs(120.0);
    assert!(sim.outcome(id).unwrap().e2e_ns.is_some());
}

#[test]
fn qwen_model_runs() {
    let cfg = RunConfig::new(SystemSpec::h200(), ModelSpec::qwen25_14b(), 8, 32);
    let mut sim = ServingSim::new(cfg);
    let id = sim.submit_at(0, ReqClass::Normal, 5_000, 4);
    sim.run_secs(120.0);
    assert!(sim.outcome(id).unwrap().e2e_ns.is_some());
}
