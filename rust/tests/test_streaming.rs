//! Streaming-vs-materialized differential acceptance: the lazy scenario
//! path (`Scenario::stream` + `ServingSim::run_streaming` +
//! `run_stream`) must reproduce the materialized path
//! (`Scenario::generate` + `run_trace`) byte-for-byte at the
//! per-request Outcome level, and within the quantile sketch's
//! advertised error bound at the report level once runs outgrow the
//! sketch's exact fallback.

use cpuslow::config::{ModelSpec, RunConfig, SystemSpec};
use cpuslow::engine::{Outcome, ReqClass, ServingSim, StreamArrival};
use cpuslow::util::stats::QuantileSketch;
use cpuslow::workload::scenario::{run_stream, run_trace, Scenario, TraceReq};

fn cfg(cores: usize) -> RunConfig {
    RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, cores)
}

fn arrival_of(r: &TraceReq) -> StreamArrival {
    StreamArrival {
        at_ns: r.at_ns,
        class: ReqClass::Normal,
        prompt_tokens: r.prompt_tokens,
        max_new_tokens: r.output_tokens,
        content_seed: r.content_seed,
        tag: r.class_idx as u32,
    }
}

fn outcomes_via<I>(cfg: RunConfig, arrivals: I, slack_s: f64) -> Vec<Outcome>
where
    I: Iterator<Item = StreamArrival> + 'static,
{
    let mut sim = ServingSim::new(cfg);
    let mut out = Vec::new();
    sim.run_streaming(arrivals, slack_s, |o| out.push(o));
    out.sort_by_key(|o| o.id);
    out
}

#[test]
fn streaming_outcomes_byte_identical_across_catalog() {
    // Every catalog scenario: drive once from the materialized trace and
    // once from the lazy k-way merge; every per-request outcome —
    // timestamps included — must be identical.
    for scenario in Scenario::catalog() {
        let scenario = scenario.with_duration(6.0);
        let seed = 11u64;
        let trace = scenario.generate(seed);
        let slack = trace.classes.iter().fold(0.0_f64, |a, c| a.max(c.slo_ttft_s)) + 1.0;
        let materialized: Vec<StreamArrival> = trace.requests.iter().map(arrival_of).collect();
        let a = outcomes_via(cfg(16), materialized.into_iter(), slack);
        let b = outcomes_via(cfg(16), scenario.stream(seed).map(|r| arrival_of(&r)), slack);
        assert!(!a.is_empty(), "{}", scenario.name);
        assert_eq!(a, b, "outcomes diverged for '{}'", scenario.name);
    }
}

#[test]
fn run_stream_report_matches_run_trace_for_small_runs() {
    // Below the sketch's exact-fallback cap the whole report — counts,
    // percentiles, GPU-idle share, step count — matches field-for-field.
    for name in ["steady", "multi-tenant", "attack"] {
        let scenario = Scenario::by_name(name).unwrap().with_duration(6.0);
        let a = run_trace(cfg(16), &scenario.generate(3));
        let b = run_stream(cfg(16), &scenario, 3);
        assert_eq!(a.issued, b.issued, "{name}");
        assert!(a.issued > 0, "{name}");
        assert!(
            (a.issued as u64) < QuantileSketch::EXACT_CAP as u64,
            "{name}: keep this run inside the exact fallback"
        );
        assert_eq!(a.timeouts, b.timeouts, "{name}");
        assert_eq!(a.steps_completed, b.steps_completed, "{name}");
        assert_eq!(a.gpu_idle_share, b.gpu_idle_share, "{name}");
        assert_eq!(a.ttft_p50_s, b.ttft_p50_s, "{name}");
        assert_eq!(a.ttft_p99_s, b.ttft_p99_s, "{name}");
        assert_eq!(a.per_class.len(), b.per_class.len(), "{name}");
        for (ca, cb) in a.per_class.iter().zip(&b.per_class) {
            assert_eq!(ca.issued, cb.issued, "{name}/{}", ca.name);
            assert_eq!(ca.timeouts, cb.timeouts, "{name}/{}", ca.name);
            assert_eq!(ca.ttft_p50_s, cb.ttft_p50_s, "{name}/{}", ca.name);
            assert_eq!(ca.ttft_p99_s, cb.ttft_p99_s, "{name}/{}", ca.name);
        }
    }
}

#[test]
fn sketch_percentiles_within_bound_beyond_exact_cap() {
    // Scale the steady scenario past the sketch's exact fallback: counts
    // still match exactly, percentiles within the documented bound.
    let scenario = Scenario::by_name("steady")
        .unwrap()
        .scaled(6.0)
        .with_duration(30.0);
    let a = run_trace(cfg(32), &scenario.generate(1));
    let b = run_stream(cfg(32), &scenario, 1);
    assert_eq!(a.issued, b.issued);
    assert!(
        a.issued > QuantileSketch::EXACT_CAP,
        "run must outgrow the exact fallback: {}",
        a.issued
    );
    assert_eq!(a.timeouts, b.timeouts);
    assert_eq!(a.steps_completed, b.steps_completed);
    let bound = QuantileSketch::relative_error_bound() * 1.5 + 1e-9;
    for (exact, sketch) in [
        (a.ttft_p50_s, b.ttft_p50_s),
        (a.ttft_p99_s, b.ttft_p99_s),
    ] {
        let (e, s) = (exact.expect("on-time requests"), sketch.expect("on-time requests"));
        let rel = (s / e - 1.0).abs();
        assert!(rel <= bound, "sketch {s} vs exact {e} (rel {rel})");
    }
}

#[test]
fn streaming_plan_backlog_stays_bounded() {
    // The plans-map eviction regression pin, exercised through the
    // streaming driver: sample the backlog while a scenario drains.
    let scenario = Scenario::by_name("steady").unwrap().with_duration(10.0);
    let mut sim = ServingSim::new(cfg(16));
    let arrivals: Vec<StreamArrival> = scenario
        .generate(5)
        .requests
        .iter()
        .map(arrival_of)
        .collect();
    for a in arrivals {
        sim.submit_request(a);
    }
    let mut max_backlog = 0;
    for k in 1..=80 {
        sim.run_secs(k as f64 * 0.25);
        max_backlog = max_backlog.max(sim.plan_backlog());
    }
    assert!(sim.steps_completed() > 50);
    assert!(max_backlog <= 1, "plan backlog {max_backlog}");
}
