use cpuslow::config::{ModelSpec, RunConfig, SystemSpec};
use cpuslow::engine::{ReqClass, ServingSim};

#[test]
#[ignore]
fn debug_victim_timeline() {
    let cfg = RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, 32);
    let mut s = ServingSim::new(cfg);
    for i in 0..160 {
        s.submit_at(i * 125_000_000, ReqClass::Attacker, 28_000, 16);
    }
    let v = s.submit_at(1_000_000_000, ReqClass::Victim, 2_800, 16);
    s.run_secs(120.0);
    let o = s.outcome(v).unwrap();
    println!("victim: tokenize={:?} ttft={:?}", o.tokenize_latency_ns.map(|n| n as f64/1e9), o.ttft_secs());
    // dump attacker first-token times for the first 12
    for id in 0..12u64 {
        let a = s.outcome(id).unwrap();
        println!("attacker {id}: arrival={:.2} tokenized=+{:.2?} ttft={:?}", a.arrival_ns as f64/1e9,
                 a.tokenize_latency_ns.map(|n| n as f64/1e9), a.ttft_secs());
    }
    println!("steps={}", s.steps_completed());
}
