//! Minimal offline stand-in for the `crossbeam-utils` crate: just
//! [`CachePadded`], which the shm broadcast ring uses to keep the
//! writer's and each reader's sequence counters on separate cache lines
//! (avoiding false sharing between the spinning sides).

use std::ops::{Deref, DerefMut};

/// Pads and aligns `T` to 128 bytes (two 64-byte lines — adjacent-line
/// prefetchers pull pairs, so 128 is the conservative choice, matching
/// what crossbeam does on x86_64).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> CachePadded<T> {
        CachePadded { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> CachePadded<T> {
        CachePadded::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_and_deref() {
        let x = CachePadded::new(7u64);
        assert_eq!(*x, 7);
        assert_eq!(std::mem::align_of::<CachePadded<u64>>(), 128);
        let boxed = Box::new(CachePadded::new(1u8));
        assert_eq!((&*boxed as *const _ as usize) % 128, 0);
    }
}
