//! Offline **stub** of the `xla` PJRT bindings.
//!
//! The real crate links the XLA native extension; this container has no
//! registry and no extension, so every entry point returns an error.
//! `cpuslow::runtime::pjrt_available()` therefore reports `false` and
//! the PJRT-backed tests, benches, and examples self-skip — the
//! type-level API is preserved so `runtime/` and `realserve/` keep
//! compiling unchanged.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT native extension not available in this build (offline xla stub)".to_string(),
    ))
}

/// Element types accepted by device buffers / literals.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

#[derive(Debug)]
pub struct PjRtDevice;

#[derive(Debug)]
pub struct PjRtBuffer;

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

#[derive(Debug)]
pub struct PjRtClient;

#[derive(Debug)]
pub struct HloModuleProto;

#[derive(Debug)]
pub struct XlaComputation;

#[derive(Debug)]
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn addressable_devices(&self) -> Vec<PjRtDevice> {
        Vec::new()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
