//! Minimal offline stand-in for the `libc` crate: exactly the Linux
//! CPU-affinity surface `realserve::affinity` uses. Raw `extern "C"`
//! declarations against the platform libc; the `cpu_set_t` layout is the
//! kernel's fixed 1024-bit mask.

#![allow(non_camel_case_types)]
// The CPU_* mask helpers deliberately keep the real libc crate's
// (C-macro-derived) uppercase names.
#![allow(non_snake_case)]

pub type c_int = i32;
pub type c_long = i64;
pub type pid_t = i32;
pub type size_t = usize;

/// glibc `sysconf` name for the number of online processors.
pub const _SC_NPROCESSORS_ONLN: c_int = 84;

const CPU_SETSIZE_WORDS: usize = 1024 / 64;

/// The kernel's 1024-bit CPU mask (16 × u64 = 128 bytes, matching
/// glibc's `cpu_set_t`).
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct cpu_set_t {
    bits: [u64; CPU_SETSIZE_WORDS],
}

pub fn CPU_ZERO(set: &mut cpu_set_t) {
    set.bits = [0; CPU_SETSIZE_WORDS];
}

pub fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
    if cpu < CPU_SETSIZE_WORDS * 64 {
        set.bits[cpu / 64] |= 1u64 << (cpu % 64);
    }
}

pub fn CPU_ISSET(cpu: usize, set: &cpu_set_t) -> bool {
    cpu < CPU_SETSIZE_WORDS * 64 && set.bits[cpu / 64] & (1u64 << (cpu % 64)) != 0
}

pub fn CPU_COUNT(set: &cpu_set_t) -> c_int {
    set.bits.iter().map(|w| w.count_ones()).sum::<u32>() as c_int
}

extern "C" {
    pub fn sched_setaffinity(pid: pid_t, cpusetsize: size_t, mask: *const cpu_set_t) -> c_int;
    pub fn sched_getaffinity(pid: pid_t, cpusetsize: size_t, mask: *mut cpu_set_t) -> c_int;
    pub fn sysconf(name: c_int) -> c_long;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_ops() {
        let mut set: cpu_set_t = unsafe { std::mem::zeroed() };
        CPU_ZERO(&mut set);
        assert_eq!(CPU_COUNT(&set), 0);
        CPU_SET(0, &mut set);
        CPU_SET(65, &mut set);
        assert!(CPU_ISSET(0, &set) && CPU_ISSET(65, &set) && !CPU_ISSET(1, &set));
        assert_eq!(CPU_COUNT(&set), 2);
        assert_eq!(std::mem::size_of::<cpu_set_t>(), 128);
    }

    #[test]
    fn sysconf_reports_processors() {
        let n = unsafe { sysconf(_SC_NPROCESSORS_ONLN) };
        assert!(n >= 1);
    }
}
