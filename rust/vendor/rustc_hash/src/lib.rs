//! Minimal offline stand-in for the `rustc-hash` crate: the classic
//! Fx multiply-rotate hash behind `HashMap`/`HashSet` type aliases.
//! Not DoS-resistant — exactly like the original — but fast and
//! deterministic, which is what the tokenizer hot paths want.

use std::hash::{BuildHasherDefault, Hasher};

pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Debug, Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for chunk in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(chunk.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            // fold the length in so "ab" and "ab\0" differ
            self.add_to_hash(u64::from_le_bytes(buf) ^ (rem.len() as u64));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }
    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add_to_hash(i as u64);
        self.add_to_hash((i >> 64) as u64);
    }
    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_set_round_trip() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        m.insert("a".into(), 1);
        m.insert("b".into(), 2);
        assert_eq!(m["a"], 1);
        let mut s: FxHashSet<u64> = FxHashSet::default();
        for i in 0..1000 {
            s.insert(i * 7);
        }
        assert_eq!(s.len(), 1000);
        assert!(s.contains(&693));
    }

    #[test]
    fn hashing_is_deterministic_and_spreads() {
        let h = |bytes: &[u8]| {
            let mut hasher = FxHasher::default();
            hasher.write(bytes);
            hasher.finish()
        };
        assert_eq!(h(b"hello"), h(b"hello"));
        assert_ne!(h(b"hello"), h(b"hellp"));
        assert_ne!(h(b"ab"), h(b"ab\0"));
    }
}
