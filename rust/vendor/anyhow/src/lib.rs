//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Provides the subset `cpuslow` uses: a string-backed [`Error`] that
//! any `std::error::Error` converts into (so `?` works), the `anyhow!`
//! / `bail!` / `ensure!` macros, and the [`Context`] extension trait.
//! Error sources are flattened into the message rather than chained.

use std::fmt;

/// A flattened, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prefix the error with context, anyhow-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// anyhow renders the full chain from Debug (what `fn main() -> Result`
// prints); mirror that by showing the message, not a struct dump.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow::Error, this type deliberately does NOT implement
// std::error::Error — that is what makes the blanket From possible.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on any displayable-error Result.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn bail_and_context() {
        fn f() -> Result<u32> {
            bail!("boom {}", 42);
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");

        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 2, "too small: {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(1).unwrap_err().to_string(), "too small: 1");
    }
}
