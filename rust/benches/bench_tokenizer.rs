//! Tokenizer benches — the L3 hot path behind Figure 5's CPU cost and
//! the calibration source for `tokenize_s_per_token`.
//!
//! Writes `BENCH_tokenizer.json` (tokens/sec and merges/sec per
//! scenario) so the encode/train hot paths are tracked across PRs;
//! `cpuslow bench-check` gates it against
//! `rust/BENCH_tokenizer.baseline.json` in CI alongside the simcpu and
//! serve suites.

use cpuslow::tokenizer::{
    corpus::Lexicon, encode_uncached, encode_uncached_into, train, BatchTokenizer, Encoder,
};
use cpuslow::util::bench::{bench, black_box, BenchSuite};
use cpuslow::util::rng::Rng;
use std::time::Duration;

fn main() {
    println!("== tokenizer benches ==");
    let mut suite = BenchSuite::new("tokenizer");
    let lex = Lexicon::generate(0xB, 1_000);
    let mut rng = Rng::new(0xC);
    let train_corpus = lex.sample_corpus(&mut rng, 32, 4_096);
    let vocab = train(&train_corpus, 2_000);

    let text_4k = lex.sample_text(&mut rng, 4_096);
    let text_64k = lex.sample_text(&mut rng, 65_536);
    let text_1m = lex.sample_text(&mut rng, 1 << 20);

    let n_tok_4k = encode_uncached(&vocab, &text_4k).len() as f64;
    let r = bench("encode_uncached 4 KB", Duration::from_secs(2), || {
        black_box(encode_uncached(&vocab, &text_4k));
    });
    r.report();
    println!(
        "    → {:.2} M tokens/s single-core ({:.0} ns/token)",
        r.per_sec(n_tok_4k) / 1e6,
        r.mean_ns / n_tok_4k
    );
    suite.record(&r, Some((n_tok_4k, "tokens")));

    let n_tok_64k = encode_uncached(&vocab, &text_64k).len() as f64;
    let r = bench("encode_uncached 64 KB", Duration::from_secs(2), || {
        black_box(encode_uncached(&vocab, &text_64k));
    });
    r.report();
    println!(
        "    → {:.2} M tokens/s single-core",
        r.per_sec(n_tok_64k) / 1e6
    );
    suite.record(&r, Some((n_tok_64k, "tokens")));

    // allocation-free variant: reused output buffer + warm merge scratch
    let mut reused = Vec::with_capacity(n_tok_64k as usize + 16);
    let r = bench("encode_into 64 KB (reused buffer)", Duration::from_secs(2), || {
        reused.clear();
        encode_uncached_into(&vocab, &text_64k, &mut reused);
        black_box(reused.len());
    });
    r.report();
    println!(
        "    → {:.2} M tokens/s single-core, zero allocs/iter",
        r.per_sec(n_tok_64k) / 1e6
    );
    suite.record(&r, Some((n_tok_64k, "tokens")));

    // cached encoder (word cache warm)
    let mut enc = Encoder::new(&vocab);
    enc.encode(&text_4k);
    let r = bench("encoder cached 4 KB", Duration::from_secs(2), || {
        black_box(enc.encode(&text_4k));
    });
    r.report();
    suite.record(&r, Some((n_tok_4k, "tokens")));

    // parallel batch (pool of 4)
    let tok = BatchTokenizer::new(vocab.clone(), 4);
    let batch: Vec<String> = (0..8).map(|_| lex.sample_text(&mut rng, 8_192)).collect();
    let total_tokens: f64 = batch
        .iter()
        .map(|t| encode_uncached(&vocab, t).len() as f64)
        .sum();
    let r = bench("batch encode 8×8 KB (4 threads)", Duration::from_secs(2), || {
        black_box(tok.encode_batch_refs(&batch));
    });
    r.report();
    println!(
        "    → {:.2} M tokens/s across pool",
        r.per_sec(total_tokens) / 1e6
    );
    suite.record(&r, Some((total_tokens, "tokens")));

    // long single document: borrowed chunks fanned across the pool
    // (the encode_long path — pre-fix this copied every chunk into an
    // owned String before dispatch)
    let n_tok_1m = encode_uncached(&vocab, &text_1m).len() as f64;
    let r = bench("encode_long 1 MB (64 KB chunks, 4 threads)", Duration::from_secs(3), || {
        black_box(tok.encode_long(&text_1m, 64 * 1024));
    });
    r.report();
    println!(
        "    → {:.2} M tokens/s across pool (long doc)",
        r.per_sec(n_tok_1m) / 1e6
    );
    suite.record(&r, Some((n_tok_1m, "tokens")));

    // decode
    let ids = encode_uncached(&vocab, &text_4k);
    let enc2 = Encoder::new(&vocab);
    let r = bench("decode 4 KB", Duration::from_secs(1), || {
        black_box(enc2.decode(&ids));
    });
    r.report();
    suite.record(&r, Some((n_tok_4k, "tokens")));

    // training
    let r = bench("train 500 merges (128 KB corpus)", Duration::from_secs(3), || {
        black_box(train(&train_corpus, 500));
    });
    r.report();
    suite.record(&r, Some((500.0, "merges")));

    let r = bench("train 2000 merges (128 KB corpus)", Duration::from_secs(4), || {
        black_box(train(&train_corpus, 2_000));
    });
    r.report();
    suite.record(&r, Some((2_000.0, "merges")));

    match suite.write(".") {
        Ok(path) => println!("bench data → {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_tokenizer.json: {e}"),
    }
}
