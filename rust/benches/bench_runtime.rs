//! Real PJRT runtime benches (Track R hot path): prefill and decode
//! step latency of the AOT-compiled tiny-100M model on this host.
//! Skips gracefully when `make artifacts` hasn't run.

use cpuslow::runtime::ModelRuntime;
use cpuslow::util::bench::{bench_n, black_box};

fn main() {
    println!("== PJRT runtime benches (tiny-100M, CPU) ==");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let t0 = std::time::Instant::now();
    let rt = match ModelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP: runtime load failed: {e}");
            return;
        }
    };
    println!("load+compile+param-upload: {:.2} s", t0.elapsed().as_secs_f64());

    let prompt: Vec<u32> = (1..=100).collect();
    let r = bench_n("prefill 100 tokens (bucket 128)", 5, || {
        black_box(rt.prefill(&prompt).unwrap());
    });
    r.report();
    let toks_per_s = 100.0 / (r.mean_ns / 1e9);
    println!("    → {toks_per_s:.0} prefill tokens/s");

    let prompt256: Vec<u32> = (1..=250).collect();
    let r = bench_n("prefill 250 tokens (bucket 256)", 3, || {
        black_box(rt.prefill(&prompt256).unwrap());
    });
    r.report();

    // decode step (batch 4)
    let out = rt.prefill(&prompt).unwrap();
    let mut state = rt.new_decode_state().unwrap();
    for lane in 0..rt.manifest().decode_batch {
        rt.insert_lane(&mut state, lane, &out, prompt.len() - 1).unwrap();
    }
    let tokens = vec![42i32; rt.manifest().decode_batch];
    let active = vec![true; rt.manifest().decode_batch];
    let r = bench_n("decode step (batch 4)", 10, || {
        black_box(rt.decode_step(&mut state, &tokens, &active).unwrap());
    });
    r.report();
    println!(
        "    → {:.1} output tokens/s at full batch",
        4.0 / (r.mean_ns / 1e9)
    );

    // attribution: cache upload alone (the host round-trip half)
    let r = bench_n("cache state upload (2×75 MB)", 5, || {
        black_box(rt.new_decode_state().unwrap());
    });
    r.report();
}
// appended: attribution micro-bench — how much of a decode step is the
// KV-cache host round-trip vs XLA compute? (perf pass, EXPERIMENTS §Perf)
