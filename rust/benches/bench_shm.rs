//! Real shm-broadcast ring benches (Figure 13's data structure, actual
//! atomics on this host): uncontended latency and 1-writer-N-reader
//! throughput as TP degree grows.
//!
//! Writes `BENCH_shm.json` (roundtrips/sec and writer msgs/sec per TP
//! degree) so the IPC hot path is tracked across PRs.

use cpuslow::ipc::ShmBroadcast;
use cpuslow::util::bench::{bench, black_box, BenchResult, BenchSuite};
use cpuslow::util::stats::Percentiles;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One full broadcast: spawn `readers` consumer threads, push `n`
/// messages through the ring, wait until every reader has consumed all
/// of them, then join. Returns the elapsed ns of the data phase only
/// (enqueue → all consumed) — thread spawn/join stays outside the
/// measurement, matching the pre-BenchSuite semantics.
fn broadcast_round(readers: usize, n: u64) -> f64 {
    let q: Arc<ShmBroadcast<u64>> = ShmBroadcast::new(256, readers);
    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..readers)
        .map(|r| {
            let q = Arc::clone(&q);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut consumed = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    if q.try_dequeue(r).is_some() {
                        consumed += 1;
                    } else {
                        std::hint::spin_loop();
                    }
                }
                // drain
                while q.try_dequeue(r).is_some() {
                    consumed += 1;
                }
                consumed
            })
        })
        .collect();
    let t0 = Instant::now();
    for i in 0..n {
        q.enqueue_spinning(i);
    }
    // wait for all readers to consume everything
    while q.min_read_seq() < n {
        std::hint::spin_loop();
    }
    let dt_ns = t0.elapsed().as_nanos() as f64;
    stop.store(true, Ordering::Relaxed);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, n * readers as u64);
    dt_ns
}

fn main() {
    println!("== shm broadcast (real atomics) ==");
    let mut suite = BenchSuite::new("shm");

    // single-threaded enqueue+dequeue round trip
    let q: Arc<ShmBroadcast<u64>> = ShmBroadcast::new(64, 1);
    let r = bench(
        "enqueue+dequeue roundtrip (1 reader)",
        Duration::from_secs(1),
        || {
            q.try_enqueue(42);
            black_box(q.try_dequeue(0));
        },
    );
    r.report();
    suite.record(&r, Some((1.0, "roundtrips")));

    // cross-thread broadcast throughput per TP degree; each round is
    // timed internally (data phase only), so spawn/join noise never
    // pollutes the recorded per_sec
    const N: u64 = 300_000;
    for readers in [1usize, 2, 4, 8] {
        let mut samples = Percentiles::new();
        for _ in 0..3 {
            samples.add(broadcast_round(readers, N));
        }
        let r = BenchResult {
            name: format!("broadcast 300k msgs to {readers} readers"),
            iters: samples.len() as u64,
            mean_ns: samples.mean(),
            median_ns: samples.median(),
            p95_ns: samples.pct(95.0),
            min_ns: samples.pct(0.0),
        };
        r.report();
        println!("    → {:.2} M msg/s writer", r.per_sec(N as f64) / 1e6);
        suite.record(&r, Some((N as f64, "msgs")));
    }

    match suite.write(".") {
        Ok(path) => println!("bench data → {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_shm.json: {e}"),
    }
}
