//! Real shm-broadcast ring benches (Figure 13's data structure, actual
//! atomics on this host): uncontended latency and 1-writer-N-reader
//! throughput as TP degree grows.

use cpuslow::ipc::ShmBroadcast;
use cpuslow::util::bench::{bench, black_box};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    println!("== shm broadcast (real atomics) ==");

    // single-threaded enqueue+dequeue round trip
    let q: Arc<ShmBroadcast<u64>> = ShmBroadcast::new(64, 1);
    let r = bench("enqueue+dequeue roundtrip (1 reader)", Duration::from_secs(1), || {
        q.try_enqueue(42);
        black_box(q.try_dequeue(0));
    });
    r.report();

    // cross-thread broadcast throughput per TP degree
    for readers in [1usize, 2, 4, 8] {
        let q: Arc<ShmBroadcast<u64>> = ShmBroadcast::new(256, readers);
        let stop = Arc::new(AtomicBool::new(false));
        let handles: Vec<_> = (0..readers)
            .map(|r| {
                let q = Arc::clone(&q);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut consumed = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        if q.try_dequeue(r).is_some() {
                            consumed += 1;
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                    // drain
                    while q.try_dequeue(r).is_some() {
                        consumed += 1;
                    }
                    consumed
                })
            })
            .collect();
        const N: u64 = 300_000;
        let t0 = std::time::Instant::now();
        for i in 0..N {
            q.enqueue_spinning(i);
        }
        // wait for all readers to consume everything
        while q.min_read_seq() < N {
            std::hint::spin_loop();
        }
        let dt = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, N * readers as u64);
        println!(
            "broadcast 300k msgs to {readers} readers: {:>8.2} ms  ({:.2} M msg/s writer)",
            dt * 1e3,
            N as f64 / dt / 1e6
        );
    }
}
