//! DES scheduler benches — the simulator's event-loop throughput bounds
//! every Track-S experiment's wall time (§Perf L3 target).
//!
//! Besides the human-readable report, writes `BENCH_simcpu.json`
//! (events/sec per scenario, measured from the simulator's own event
//! counter) so the perf trajectory is tracked across PRs.

use cpuslow::simcpu::script::Script;
use cpuslow::simcpu::{Op, Sim, SimParams, TaskCtx};
use cpuslow::util::bench::{bench_n, black_box, BenchSuite};

fn params(cores: usize) -> SimParams {
    SimParams {
        cores,
        context_switch_ns: 3_000,
        timeslice_ns: 1_000_000,
        poll_quantum_ns: 1_000,
        trace_bucket_ns: None,
    }
}

/// Run a scenario once to count its (deterministic) events, then bench
/// it and record events/sec.
fn scenario(suite: &mut BenchSuite, name: &str, n: usize, build: impl Fn() -> Sim) {
    let events = {
        let mut sim = build();
        sim.run();
        sim.stats().events_processed
    };
    let r = bench_n(name, n, || {
        let mut sim = build();
        black_box(sim.run());
    });
    r.report();
    println!(
        "    → {} events/run, ~{:.2} M events/s",
        events,
        r.per_sec(events as f64) / 1e6
    );
    suite.record(&r, Some((events as f64, "events")));
}

fn main() {
    println!("== simcpu benches ==");
    let mut suite = BenchSuite::new("simcpu");

    // Pure compute churn: 64 tasks × 100 ms on 8 cores → ~800k slice events.
    scenario(&mut suite, "64 hogs × 100ms on 8 cores", 5, || {
        let mut sim = Sim::new(params(8));
        for _ in 0..64 {
            sim.spawn("hog", Script::new().compute(100_000_000));
        }
        sim
    });

    // Gate signal/wake storm.
    scenario(&mut suite, "10k block/signal pairs", 10, || {
        let mut sim = Sim::new(params(4));
        let gate = sim.new_gate();
        for i in 0..100u64 {
            let mut state = 0u64;
            sim.spawn("waiter", move |_ctx: &mut TaskCtx| {
                state += 1;
                if state > 100 {
                    Op::Done
                } else {
                    Op::Block {
                        gate,
                        target: i * 100 + state,
                    }
                }
            });
        }
        for t in 0..10_000u64 {
            sim.call_at(t * 1_000, move |sim| sim.signal(gate, 1));
        }
        sim
    });

    // Busy-poll contention: 8 pollers + 8 hogs on 4 cores for 100 ms.
    scenario(&mut suite, "8 pollers + 8 hogs, 100ms virtual", 5, || {
        let mut sim = Sim::new(params(4));
        let gate = sim.new_gate();
        for _ in 0..8 {
            sim.spawn("poller", Script::new().busy_poll(gate, 1));
        }
        for _ in 0..8 {
            sim.spawn("hog", Script::new().compute(100_000_000));
        }
        sim.call_at(100_000_000, move |sim| sim.signal(gate, 1));
        sim
    });

    // Timer storm across wheel levels: 2k sleepers with wake times
    // spread over 10 s of virtual time (the hierarchical timing wheel's
    // cascade path), re-sleeping five times each.
    scenario(&mut suite, "2k sleepers × 5 naps over 10s", 5, || {
        let mut sim = Sim::new(params(8));
        for i in 0..2_000u64 {
            let mut s = Script::new();
            for nap in 0..5u64 {
                s = s
                    .sleep(1_000 + (i * 4_999 + nap * 911_373) % 2_000_000_000)
                    .compute(10_000);
            }
            sim.spawn("sleeper", s);
        }
        sim
    });

    // Many-core poll fan-out: the scenario the gate→core index targets —
    // 32 cores of pollers being signalled at a high rate.
    scenario(&mut suite, "32 pollers on 32 cores, 20k signals", 5, || {
        let mut sim = Sim::new(params(32));
        let gate = sim.new_gate();
        for _ in 0..32 {
            sim.spawn("poller", Script::new().busy_poll(gate, 20_000));
        }
        for t in 0..20_000u64 {
            sim.call_at(t * 5_000, move |sim| sim.signal(gate, 1));
        }
        sim
    });

    match suite.write(".") {
        Ok(path) => println!("bench data → {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_simcpu.json: {e}"),
    }
}
