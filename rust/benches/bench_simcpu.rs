//! DES scheduler benches — the simulator's event-loop throughput bounds
//! every Track-S experiment's wall time (§Perf L3 target).

use cpuslow::simcpu::script::Script;
use cpuslow::simcpu::{Op, Sim, SimParams, TaskCtx};
use cpuslow::util::bench::{bench_n, black_box};

fn params(cores: usize) -> SimParams {
    SimParams {
        cores,
        context_switch_ns: 3_000,
        timeslice_ns: 1_000_000,
        poll_quantum_ns: 1_000,
        trace_bucket_ns: None,
    }
}

fn main() {
    println!("== simcpu benches ==");

    // Pure compute churn: 64 tasks × 100 ms on 8 cores → ~800k slice events.
    let r = bench_n("64 hogs × 100ms on 8 cores", 5, || {
        let mut sim = Sim::new(params(8));
        for _ in 0..64 {
            sim.spawn("hog", Script::new().compute(100_000_000));
        }
        black_box(sim.run());
    });
    r.report();
    let events = 64.0 * 100.0 * 8.0; // ≈ slices
    println!(
        "    → ~{:.2} M slice-events/s",
        r.per_sec(events) / 1e6
    );

    // Gate signal/wake storm.
    let r = bench_n("10k block/signal pairs", 10, || {
        let mut sim = Sim::new(params(4));
        let gate = sim.new_gate();
        for i in 0..100u64 {
            let mut state = 0u64;
            sim.spawn("waiter", move |_ctx: &mut TaskCtx| {
                state += 1;
                if state > 100 {
                    Op::Done
                } else {
                    Op::Block {
                        gate,
                        target: i * 100 + state,
                    }
                }
            });
        }
        for t in 0..10_000u64 {
            sim.call_at(t * 1_000, move |sim| sim.signal(gate, 1));
        }
        black_box(sim.run());
    });
    r.report();

    // Busy-poll contention: 8 pollers + 8 hogs on 4 cores for 100 ms.
    let r = bench_n("8 pollers + 8 hogs, 100ms virtual", 5, || {
        let mut sim = Sim::new(params(4));
        let gate = sim.new_gate();
        for _ in 0..8 {
            sim.spawn("poller", Script::new().busy_poll(gate, 1));
        }
        for _ in 0..8 {
            sim.spawn("hog", Script::new().compute(100_000_000));
        }
        sim.call_at(100_000_000, move |sim| sim.signal(gate, 1));
        black_box(sim.run());
    });
    r.report();
}
