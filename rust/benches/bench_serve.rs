//! Serving-engine benches — requests/sec through the full Track-S stack
//! (tokenizer pool → EngineCore → shm ring → GPU workers) for
//! representative catalog scenarios at small and large request counts,
//! plus an allocation profile (counting-allocator bytes as a peak-RSS
//! proxy). Writes `BENCH_serve.json` via `BenchSuite`; `cpuslow
//! bench-check` gates the `per_sec` fields against
//! `BENCH_serve.baseline.json`.

use cpuslow::config::{ModelSpec, RouterPolicy, RunConfig, SystemSpec};
use cpuslow::testkit::alloc::{self, CountingAlloc};
use cpuslow::util::bench::{bench_n, black_box, BenchSuite};
use cpuslow::workload::scenario::{run_stream, Scenario};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn cfg() -> RunConfig {
    RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, 16)
}

/// Bench one scenario cell end to end through the streaming driver.
fn cell(
    suite: &mut BenchSuite,
    config: &RunConfig,
    name: &str,
    rate_scale: f64,
    duration_s: f64,
    label: &str,
) {
    const RUNS: u64 = 3;
    let scenario = Scenario::by_name(name)
        .unwrap()
        .scaled(rate_scale)
        .with_duration(duration_s);
    // One priming run pins the deterministic request count.
    let issued = run_stream(config.clone(), &scenario, 0).issued;
    alloc::reset_peak_live();
    let live_floor = alloc::live_bytes();
    let before = alloc::counters();
    let r = bench_n(label, RUNS as usize, || {
        black_box(run_stream(config.clone(), &scenario, 0).issued);
    });
    let after = alloc::counters();
    r.report();
    let allocs_per_run = (after.allocs - before.allocs) / RUNS;
    let bytes_per_run = (after.alloc_bytes - before.alloc_bytes) / RUNS;
    let peak_live = alloc::peak_live_bytes() - live_floor;
    println!(
        "    → {issued} requests/run, {:.0} req/s; {allocs_per_run} allocs \
         ({:.0} B/request), peak live {} KiB",
        r.per_sec(issued as f64),
        bytes_per_run as f64 / issued.max(1) as f64,
        peak_live / 1024,
    );
    suite.record(&r, Some((issued as f64, "requests")));
}

fn main() {
    println!("== serving engine benches ==");
    let mut suite = BenchSuite::new("serve");

    let base = cfg();

    // Small cells: catalog defaults compressed into an 8 s window.
    cell(&mut suite, &base, "steady", 1.0, 8.0, "steady 8s (small)");
    cell(&mut suite, &base, "bursty", 1.0, 8.0, "bursty 8s (small)");
    cell(&mut suite, &base, "heavy-tail", 1.0, 8.0, "heavy-tail 8s (small)");

    // Resilience cell: flash-crowd arms admission control, shedding,
    // the deadline watchdog, and client-side retry — the full
    // resilience layer on the hot path, including never-fit rejections.
    cell(&mut suite, &base, "flash-crowd", 1.0, 8.0, "flash-crowd 8s (resilience)");

    // Priority cell: the overload-survival scenario with the full
    // ladder armed (priority admission + recompute preemption, priority
    // tokenizer queue, brownout) — the preempt/re-admit and
    // probe-window machinery on the hot path under KV pressure.
    cell(
        &mut suite,
        &base,
        "priority-flash-crowd",
        1.0,
        8.0,
        "priority-flash-crowd 8s (priority)",
    );

    // Fleet cell: the steady workload spread across four replicas
    // behind the least-loaded router, health probes and failure-aware
    // transitions armed — routing/probe overhead on a healthy fleet
    // under steady load, no faults firing.
    let mut fleet = cfg();
    fleet.serve.fleet.replicas = 4;
    fleet.serve.fleet.router = RouterPolicy::LeastLoaded;
    fleet.serve.fleet.failure_aware = true;
    cell(&mut suite, &fleet, "steady", 1.0, 8.0, "steady 8s fleet x4");

    // Profiled cell: the steady small cell with attribution profiling
    // armed. Profiling is observation-only and allocation-free in
    // steady state, so this cell's per_sec should track the unprofiled
    // steady cell; a widening gap flags overhead creeping into the
    // record/charge hot paths.
    let mut profiled = cfg();
    profiled.serve.profile = true;
    cell(&mut suite, &profiled, "steady", 1.0, 8.0, "steady 8s profiled");

    // Large cells: ~10× the offered request volume, same shapes.
    cell(&mut suite, &base, "steady", 5.0, 16.0, "steady x5 16s (large)");
    cell(&mut suite, &base, "bursty", 5.0, 16.0, "bursty x5 16s (large)");
    cell(&mut suite, &base, "heavy-tail", 5.0, 16.0, "heavy-tail x5 16s (large)");

    match suite.write(".") {
        Ok(path) => println!("bench data → {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
