//! End-to-end figure benches: one scaled-down cell per paper
//! table/figure, timing the full harness path (workload → engine →
//! metrics). These are the `cargo bench` entries promised in DESIGN.md;
//! the full-resolution sweeps run via `cpuslow experiment <id>`.
//!
//! Writes `BENCH_figures.json` (cells/sec per scenario) so the harness
//! path's perf trajectory is tracked across PRs alongside the event-loop
//! and tokenizer suites.

use cpuslow::cluster::{analyze, generate_instructional};
use cpuslow::config::{ModelSpec, RunConfig, SystemSpec};
use cpuslow::experiments::fig12::run_microbench;
use cpuslow::experiments::fig13::run_dequeue_bench;
use cpuslow::util::bench::{bench_n, black_box, BenchSuite};
use cpuslow::workload::{run_attacker_victim, run_batch, AvSpec};

fn main() {
    println!("== figure-cell benches (scaled-down) ==");
    let mut suite = BenchSuite::new("figures");

    // Fig 3/4 cell: 100k records generate + analyze
    let r = bench_n("fig3 cell: 100k salloc records", 5, || {
        let records = generate_instructional(1, 100_000);
        black_box(analyze(&records));
    });
    r.report();
    suite.record(&r, Some((100_000.0, "records")));

    // Fig 5 cell: one batch×SL point
    let r = bench_n("fig5 cell: batch 8 × 16k tokens", 3, || {
        let cfg = RunConfig::new(SystemSpec::h200(), ModelSpec::llama31_8b(), 4, 16);
        black_box(run_batch(cfg, 8, 16_000, 1, 600.0));
    });
    r.report();
    suite.record(&r, Some((1.0, "cells")));

    // Fig 7 cell: one attacker/victim point (short attack)
    let spec = AvSpec {
        attacker_sl: 57_000,
        rps: 8.0,
        attack_secs: 15.0,
        victim_start_secs: 5.0,
        n_victims: 1,
        max_new_tokens: 8,
        timeout_secs: 60.0,
        ..AvSpec::default()
    };
    let r = bench_n("fig7 cell: 57k attack @8rps, 5 cores", 3, || {
        let cfg = RunConfig::new(SystemSpec::blackwell(), ModelSpec::llama31_8b(), 4, 5);
        black_box(run_attacker_victim(cfg, &spec));
    });
    r.report();
    suite.record(&r, Some((1.0, "cells")));

    // Fig 12 cell: collective microbench
    let r = bench_n("fig12 cell: 4 ranks × 100 iters", 5, || {
        black_box(run_microbench(&SystemSpec::h100(), 4, 2, 100, 1.0, 0.3));
    });
    r.report();
    suite.record(&r, Some((1.0, "cells")));

    // Fig 13 cell: dequeue contention point
    let r = bench_n("fig13 cell: TP=4 dequeue, 20s virtual", 3, || {
        black_box(run_dequeue_bench(
            &SystemSpec::h100(),
            6,
            4,
            100,
            44.0,
            5.0,
            100_000,
            20.0,
        ));
    });
    r.report();
    suite.record(&r, Some((1.0, "cells")));

    match suite.write(".") {
        Ok(path) => println!("bench data → {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_figures.json: {e}"),
    }
}
