"""AOT lowering: JAX → HLO text artifacts + parameter blob.

Emits (under artifacts/):
  model_prefill_<S>.hlo.txt  — prefill entry for each prefill bucket S
  model_decode_b<B>.hlo.txt  — batched decode entry
  params.bin                 — flat f32 parameter arrays (spec order)
  manifest.json              — shapes/dtypes contract for the Rust runtime

Lowered with return_tuple=False: the entry computation has multiple
root outputs, which PJRT returns as separate buffers — the Rust runtime
feeds the KV-cache output buffers of step N directly into step N+1
without host copies.

Interchange is **HLO text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_BUCKETS = [128, 256, 512]
DECODE_BATCH = 4


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_prefill(seq_len, cfg=M.TinyConfig):
    spec = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_spec(cfg)]
    tokens = jax.ShapeDtypeStruct((1, seq_len), jnp.int32)
    fn = M.prefill_fn(seq_len, cfg)
    return jax.jit(fn).lower(*spec, tokens)


def lower_decode(batch, cfg=M.TinyConfig):
    spec = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_spec(cfg)]
    token = jax.ShapeDtypeStruct((batch,), jnp.int32)
    caches = jax.ShapeDtypeStruct(
        (batch, cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
    )
    lengths = jax.ShapeDtypeStruct((batch,), jnp.int32)
    fn = M.decode_fn(batch, cfg.max_seq, cfg)
    return jax.jit(fn).lower(*spec, token, caches, caches, lengths)


def write_params(path, seed=0, cfg=M.TinyConfig):
    """params.bin: [u32 n_arrays] then per array [u32 rank, u32 dims...,
    f32 data...] — little-endian, matching rust/src/runtime/params.rs."""
    params = M.init_params(seed, cfg)
    with open(path, "wb") as f:
        f.write(struct.pack("<I", len(params)))
        for arr in params:
            import numpy as np

            a = np.asarray(arr, dtype="<f4")
            f.write(struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            f.write(a.tobytes())
    return sum(int(jnp.size(p)) for p in params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-params", action="store_true")
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)
    cfg = M.TinyConfig

    manifest = {
        "model": "tiny-100M",
        "config": cfg.dims(),
        "n_param_arrays": len(M.param_spec(cfg)),
        "n_params": M.n_params(cfg),
        "prefill_buckets": PREFILL_BUCKETS,
        "decode_batch": DECODE_BATCH,
        "entries": {},
    }

    for s in PREFILL_BUCKETS:
        name = f"model_prefill_{s}.hlo.txt"
        text = to_hlo_text(lower_prefill(s, cfg))
        with open(os.path.join(out, name), "w") as f:
            f.write(text)
        manifest["entries"][f"prefill_{s}"] = {
            "file": name,
            "tokens_shape": [1, s],
            "outputs": ["logits[1,vocab]", f"k[{cfg.n_layers},{s},{cfg.n_heads},{cfg.d_head}]",
                        f"v[{cfg.n_layers},{s},{cfg.n_heads},{cfg.d_head}]"],
        }
        print(f"wrote {name} ({len(text)/1e6:.1f} MB)")

    name = f"model_decode_b{DECODE_BATCH}.hlo.txt"
    text = to_hlo_text(lower_decode(DECODE_BATCH, cfg))
    with open(os.path.join(out, name), "w") as f:
        f.write(text)
    manifest["entries"]["decode"] = {
        "file": name,
        "batch": DECODE_BATCH,
        "max_seq": cfg.max_seq,
    }
    print(f"wrote {name} ({len(text)/1e6:.1f} MB)")

    if not args.skip_params:
        n = write_params(os.path.join(out, "params.bin"), args.seed, cfg)
        print(f"wrote params.bin ({n} params)")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
