"""L2: the tiny-100M decoder-only transformer (JAX), calling the L1
Pallas attention kernel on the prefill path.

Architecture (must match `ModelSpec::tiny_100m()` on the Rust side):
  vocab 8192, d_model 768, 8 layers, 12 heads (d_head 64), d_ff 3072,
  pre-LN (RMSNorm), GELU MLP, learned absolute position embeddings,
  untied LM head. f32 throughout (CPU PJRT backend).

Two entry points are AOT-lowered by `aot.py`:

  * `prefill(params, tokens[1, S])` → (logits_last[1, V], k_cache, v_cache)
    Full-prompt prefill via the Pallas flash-attention kernel; returns
    the KV cache for subsequent decoding.
  * `decode(params, token[B], k_caches, v_caches, lengths[B])` →
    (logits[B, V], new_k, new_v)
    One decode step per batch lane against per-lane KV caches with
    per-lane lengths (continuous batching on the Rust side maps active
    requests onto lanes).

Python never runs at serving time: these functions exist to be lowered
to HLO text once (`make artifacts`).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention_causal


class TinyConfig:
    vocab = 8192
    d_model = 768
    n_layers = 12
    n_heads = 12
    d_head = 64
    d_ff = 3072
    max_seq = 512

    @classmethod
    def dims(cls):
        return dict(
            vocab=cls.vocab,
            d_model=cls.d_model,
            n_layers=cls.n_layers,
            n_heads=cls.n_heads,
            d_head=cls.d_head,
            d_ff=cls.d_ff,
            max_seq=cls.max_seq,
        )


def param_spec(cfg=TinyConfig):
    """Ordered (name, shape) list — the flattening contract shared with
    the Rust runtime (params.bin is written in exactly this order)."""
    spec = [
        ("tok_embed", (cfg.vocab, cfg.d_model)),
        ("pos_embed", (cfg.max_seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w_up", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_down", (cfg.d_ff, cfg.d_model)),
        ]
    spec += [
        ("ln_f", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return spec


def init_params(seed=0, cfg=TinyConfig):
    """Deterministic init; returns a flat list of arrays in spec order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if "embed" in name else (2.0 / (fan_in + shape[-1])) ** 0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def n_params(cfg=TinyConfig):
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def _unflatten(params, cfg=TinyConfig):
    spec = param_spec(cfg)
    assert len(params) == len(spec), f"{len(params)} vs {len(spec)}"
    return {name: p for (name, _), p in zip(spec, params)}


def rmsnorm(x, w, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def _layer_prefill(p, i, x, cfg):
    """One transformer layer over [S, D] with causal Pallas attention.
    Returns (x, k[S,H,Dh], v[S,H,Dh])."""
    s = x.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    xn = rmsnorm(x, p[f"l{i}.ln1"])
    q = (xn @ p[f"l{i}.wq"]).reshape(s, h, dh)
    k = (xn @ p[f"l{i}.wk"]).reshape(s, h, dh)
    v = (xn @ p[f"l{i}.wv"]).reshape(s, h, dh)
    # [H, S, Dh] for the kernel
    attn = flash_attention_causal(
        q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2)
    )
    attn = attn.transpose(1, 0, 2).reshape(s, cfg.d_model)
    x = x + attn @ p[f"l{i}.wo"]
    xn = rmsnorm(x, p[f"l{i}.ln2"])
    x = x + jax.nn.gelu(xn @ p[f"l{i}.w_up"]) @ p[f"l{i}.w_down"]
    return x, k, v


def prefill(params, tokens, cfg=TinyConfig):
    """Full-prompt prefill.

    Args:
      params: flat param list (spec order).
      tokens: [1, S] int32, S ≤ cfg.max_seq (padded with zeros past the
        true length is fine — caller uses logits at its true last
        position; here we return the full last-position logits for S).

    Returns:
      (logits[1, vocab] at position S-1,
       k_cache [n_layers, S, heads, d_head],
       v_cache [n_layers, S, heads, d_head])
    """
    p = _unflatten(params, cfg)
    s = tokens.shape[1]
    x = p["tok_embed"][tokens[0]] + p["pos_embed"][:s]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        x, k, v = _layer_prefill(p, i, x, cfg)
        ks.append(k)
        vs.append(v)
    x = rmsnorm(x, p["ln_f"])
    logits = x[-1:] @ p["lm_head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def _layer_decode(p, i, x, k_cache, v_cache, length, pos, cfg):
    """One layer, one token, one batch lane.

    x: [D]; k_cache/v_cache: [maxS, H, Dh]; length: scalar int32 =
    number of valid cached positions (this token attends to cache[0..length]
    plus itself, written at index `pos` = length).
    """
    h, dh = cfg.n_heads, cfg.d_head
    max_s = k_cache.shape[0]
    xn = rmsnorm(x, p[f"l{i}.ln1"])
    q = (xn @ p[f"l{i}.wq"]).reshape(h, dh)
    k_new = (xn @ p[f"l{i}.wk"]).reshape(h, dh)
    v_new = (xn @ p[f"l{i}.wv"]).reshape(h, dh)
    k_cache = jax.lax.dynamic_update_index_in_dim(k_cache, k_new, pos, axis=0)
    v_cache = jax.lax.dynamic_update_index_in_dim(v_cache, v_new, pos, axis=0)
    # attention over cache[0..=pos]
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("hd,shd->hs", q, k_cache) * scale  # [H, maxS]
    valid = jax.lax.iota(jnp.int32, max_s) <= pos
    scores = jnp.where(valid[None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("hs,shd->hd", w, v_cache).reshape(cfg.d_model)
    x = x + attn @ p[f"l{i}.wo"]
    xn = rmsnorm(x, p[f"l{i}.ln2"])
    x = x + jax.nn.gelu(xn @ p[f"l{i}.w_up"]) @ p[f"l{i}.w_down"]
    _ = length
    return x, k_cache, v_cache


def decode(params, token, k_caches, v_caches, lengths, cfg=TinyConfig):
    """One decode step for a batch of lanes.

    Args:
      token: [B] int32 — the token just sampled per lane.
      k_caches/v_caches: [B, n_layers, maxS, H, Dh].
      lengths: [B] int32 — valid cache length per lane; the new token is
        written at index `lengths[b]` and attends to [0..lengths[b]].

    Returns:
      (logits [B, vocab], new k_caches, new v_caches)
    """
    p = _unflatten(params, cfg)

    def lane(tok, kc, vc, length):
        x = p["tok_embed"][tok] + p["pos_embed"][length]
        new_k, new_v = [], []
        for i in range(cfg.n_layers):
            x, k_i, v_i = _layer_decode(p, i, x, kc[i], vc[i], length, length, cfg)
            new_k.append(k_i)
            new_v.append(v_i)
        x = rmsnorm(x, p["ln_f"])
        return x @ p["lm_head"], jnp.stack(new_k), jnp.stack(new_v)

    return jax.vmap(lane)(token, k_caches, v_caches, lengths)


def prefill_fn(seq_len, cfg=TinyConfig):
    """Concrete-shape prefill callable for AOT lowering."""
    def fn(*args):
        params = list(args[:-1])
        tokens = args[-1]
        logits, k, v = prefill(params, tokens, cfg)
        return (logits, k, v)

    return fn


def decode_fn(batch, max_seq, cfg=TinyConfig):
    """Concrete-shape decode callable for AOT lowering."""
    def fn(*args):
        n = len(param_spec(cfg))
        params = list(args[:n])
        token, k_caches, v_caches, lengths = args[n:]
        logits, k, v = decode(params, token, k_caches, v_caches, lengths, cfg)
        return (logits, k, v)

    return fn
