"""Pure-jnp oracle for the L1 attention kernel.

The reference implements exact causal softmax attention with the same
numerics contract as the Pallas kernel (f32 accumulation, max-subtracted
softmax). Every kernel test asserts allclose against this.
"""

import jax
import jax.numpy as jnp


def causal_attention_ref(q, k, v, scale=None):
    """Exact causal attention.

    Args:
      q, k, v: [seq, d_head] (single head).
      scale: softmax scale; defaults to 1/sqrt(d_head).

    Returns:
      [seq, d_head] attention output.
    """
    s, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = (q @ k.T) * scale  # [s, s]
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return weights @ v


def mha_causal_ref(q, k, v, scale=None):
    """Multi-head causal attention over [heads, seq, d_head]."""
    return jax.vmap(lambda qq, kk, vv: causal_attention_ref(qq, kk, vv, scale))(q, k, v)
