"""L1: FlashAttention-style causal attention as a Pallas kernel.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper's
serving stack runs FlashAttention on NVIDIA GPUs, where the kernel tiles
Q/K/V into *shared memory* per threadblock and drives tensor cores. On
the TPU-flavored Pallas model the same insight maps to:

  * BlockSpec moves (block_q × d) Q tiles and the full K/V rows
    HBM→VMEM per grid step — VMEM plays the role of shared memory
    (software-managed scratchpad, ~16 MB/core, so tiles can be far
    larger than a GPU's 48–228 KB SMEM).
  * The QKᵀ and PV matmuls are MXU-shaped (128×128 systolic array):
    block_q and d_head are kept multiples of 128/64 so each tile maps
    onto full MXU passes instead of WMMA fragments.
  * The online-softmax running max/denominator live in VMEM scratch
    (f32), matching FlashAttention's register accumulators.

The grid iterates (head, q_block); each step scans K/V blocks with an
online-softmax accumulator, skipping fully-masked KV blocks (causal).
`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so real-TPU lowering is compile-only (see DESIGN.md §Perf
for the VMEM/MXU estimates).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_k, seq_len):
    """One (head, q_block) grid step: online-softmax scan over KV blocks."""
    qi = pl.program_id(1)
    q = q_ref[...] * scale  # [block_q, d]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # [block_q]

    d = q_ref.shape[-1]
    # Online-softmax state: running max m, denominator l, accumulator acc.
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    # Causal: KV blocks strictly after this Q block contribute nothing.
    n_kv_blocks = (qi + 1) * (block_q // block_k)

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kv_i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kv_i * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # MXU
        k_pos = kv_i * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        # rescale previous accumulator
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention_causal(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Causal multi-head attention via the Pallas kernel.

    Args:
      q, k, v: [heads, seq, d_head]; seq must be a multiple of block_q
        and block_q a multiple of block_k.

    Returns:
      [heads, seq, d_head]
    """
    h, s, d = q.shape
    if s % block_q != 0:
        # fall back to the largest divisor of s that fits the budget
        block_q = next(b for b in range(min(block_q, s), 0, -1) if s % b == 0)
    if block_q % block_k != 0:
        block_k = next(b for b in range(min(block_k, block_q), 0, -1) if block_q % b == 0)
    assert s % block_q == 0, f"seq {s} % block_q {block_q} != 0"
    assert block_q % block_k == 0
    scale = 1.0 / (d ** 0.5)

    grid = (h, s // block_q)
    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k, seq_len=s
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Q: one [block_q, d] tile per grid step → VMEM
            pl.BlockSpec((None, block_q, d), lambda hi, qi: (hi, qi, 0)),
            # K/V: full rows for the head (scanned block-wise inside)
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


def vmem_footprint_bytes(block_q, block_k, seq, d, dtype_bytes=4):
    """Estimated VMEM bytes resident per grid step (for DESIGN.md §Perf).

    Q tile + full K/V rows + accumulator + output tile. On a real TPU the
    K/V scan would stream block_k-sized tiles instead of holding full
    rows; both variants are reported by `python -m compile.kernels.attention`.
    """
    q_tile = block_q * d * dtype_bytes
    kv_full = 2 * seq * d * dtype_bytes
    kv_stream = 2 * block_k * d * dtype_bytes
    acc = block_q * d * 4 + 2 * block_q * 4
    out = block_q * d * dtype_bytes
    return {
        "resident_full_kv": q_tile + kv_full + acc + out,
        "resident_streamed_kv": q_tile + kv_stream + acc + out,
    }


def mxu_utilization_estimate(block_q, block_k, d):
    """Fraction of MXU-pass capacity used by each QKᵀ/PV tile matmul.

    The MXU processes 128×128×128 passes; utilization is the product of
    per-dimension fill ratios.
    """
    fill = lambda n: min(n, 128) / 128.0
    return fill(block_q) * fill(block_k) * fill(d)


if __name__ == "__main__":
    for bq, bk, s, d in [(128, 128, 1024, 64), (256, 128, 2048, 64), (128, 64, 512, 64)]:
        fp = vmem_footprint_bytes(bq, bk, s, d)
        print(
            f"block_q={bq} block_k={bk} seq={s} d={d}: "
            f"VMEM full-kv={fp['resident_full_kv']/1e6:.2f} MB "
            f"streamed={fp['resident_streamed_kv']/1e3:.1f} KB "
            f"MXU util={mxu_utilization_estimate(bq, bk, d):.2f}"
        )
