"""L1 correctness: Pallas flash-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes per the repro contract; the kernel
must match `ref.py` to tight f32 tolerances on every draw.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    flash_attention_causal,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import causal_attention_ref, mha_causal_ref


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype) * scale


class TestKernelBasics:
    def test_matches_ref_single_head(self):
        q, k, v = (rand(i, (1, 256, 64)) for i in range(3))
        out = flash_attention_causal(q, k, v)
        ref = mha_causal_ref(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_matches_ref_multi_head(self):
        q, k, v = (rand(i + 10, (12, 128, 64)) for i in range(3))
        out = flash_attention_causal(q, k, v)
        ref = mha_causal_ref(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_causality(self):
        """Changing future K/V must not change past outputs."""
        q, k, v = (rand(i + 20, (2, 128, 64)) for i in range(3))
        out1 = flash_attention_causal(q, k, v)
        k2 = k.at[:, 100:, :].set(99.0)
        v2 = v.at[:, 100:, :].set(-99.0)
        out2 = flash_attention_causal(q, k2, v2)
        np.testing.assert_allclose(out1[:, :100], out2[:, :100], rtol=1e-5, atol=1e-5)
        assert not np.allclose(out1[:, 100:], out2[:, 100:])

    def test_first_position_is_v0(self):
        """Position 0 attends only to itself → output = v[0]."""
        q, k, v = (rand(i + 30, (1, 128, 64)) for i in range(3))
        out = flash_attention_causal(q, k, v)
        np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-5, atol=1e-5)

    def test_uniform_values(self):
        """With identical V rows the output equals that row everywhere."""
        q = rand(40, (1, 128, 64))
        k = rand(41, (1, 128, 64))
        v = jnp.ones((1, 128, 64)) * 0.5
        out = flash_attention_causal(q, k, v)
        np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)

    def test_large_magnitude_stability(self):
        """Online softmax must not overflow on large scores."""
        q, k, v = (rand(i + 50, (1, 128, 64), scale=30.0) for i in range(3))
        out = flash_attention_causal(q, k, v)
        assert np.isfinite(np.asarray(out)).all()
        ref = mha_causal_ref(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_custom_blocks(self):
        q, k, v = (rand(i + 60, (2, 256, 64)) for i in range(3))
        out_default = flash_attention_causal(q, k, v)
        out_small = flash_attention_causal(q, k, v, block_q=64, block_k=32)
        np.testing.assert_allclose(out_default, out_small, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([64, 128, 192, 256]),
    d=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_hypothesis(heads, seq, d, seed):
    q = rand(seed, (heads, seq, d))
    k = rand(seed + 1, (heads, seq, d))
    v = rand(seed + 2, (heads, seq, d))
    out = flash_attention_causal(q, k, v)
    ref = mha_causal_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


@settings(max_examples=10, deadline=None)
@given(
    scale=st.sampled_from([0.01, 1.0, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_kernel_value_scale_sweep(scale, seed):
    q = rand(seed, (2, 128, 64), scale=scale)
    k = rand(seed + 1, (2, 128, 64), scale=scale)
    v = rand(seed + 2, (2, 128, 64), scale=scale)
    out = flash_attention_causal(q, k, v)
    ref = mha_causal_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4 * max(scale, 1.0))


class TestRoofline:
    def test_vmem_footprint_within_budget(self):
        """Default tiling must fit a TPU core's ~16 MB VMEM."""
        fp = vmem_footprint_bytes(128, 128, 2048, 64)
        assert fp["resident_full_kv"] < 16e6
        assert fp["resident_streamed_kv"] < 1e6

    def test_mxu_utilization(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(128, 128, 64) == 0.5
        assert mxu_utilization_estimate(64, 64, 64) == 0.125


def test_ref_self_consistency():
    """Oracle sanity: softmax rows sum to 1 (implicitly) — a uniform-V
    input returns V."""
    q = rand(70, (64, 32))
    k = rand(71, (64, 32))
    v = jnp.ones((64, 32)) * 2.0
    out = causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)
