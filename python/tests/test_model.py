"""L2 correctness: transformer shapes, decode/prefill agreement, and the
AOT lowering contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


class SmallCfg(M.TinyConfig):
    """A shrunk config so model tests run in seconds."""

    vocab = 512
    d_model = 64
    n_layers = 2
    n_heads = 2
    d_head = 32
    d_ff = 128
    max_seq = 128


@pytest.fixture(scope="module")
def params():
    return M.init_params(0, SmallCfg)


def toks(n, seed=3):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(1, SmallCfg.vocab, size=(1, n)), jnp.int32)


class TestShapes:
    def test_param_spec_count_matches_init(self, params):
        assert len(params) == len(M.param_spec(SmallCfg))
        for p, (_, shape) in zip(params, M.param_spec(SmallCfg)):
            assert tuple(p.shape) == tuple(shape)

    def test_n_params_consistent(self, params):
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == M.n_params(SmallCfg)

    def test_tiny_config_is_about_100m(self):
        assert 0.6e8 <= M.n_params(M.TinyConfig) <= 1.5e8

    def test_prefill_shapes(self, params):
        logits, k, v = M.prefill(params, toks(64), SmallCfg)
        assert logits.shape == (1, SmallCfg.vocab)
        assert k.shape == (SmallCfg.n_layers, 64, SmallCfg.n_heads, SmallCfg.d_head)
        assert v.shape == k.shape

    def test_decode_shapes(self, params):
        b = 3
        caches = jnp.zeros(
            (b, SmallCfg.n_layers, SmallCfg.max_seq, SmallCfg.n_heads, SmallCfg.d_head)
        )
        logits, k, v = M.decode(
            params,
            jnp.array([1, 2, 3], jnp.int32),
            caches,
            caches,
            jnp.array([0, 5, 10], jnp.int32),
            SmallCfg,
        )
        assert logits.shape == (b, SmallCfg.vocab)
        assert k.shape == caches.shape


class TestNumerics:
    def test_decode_matches_prefill(self, params):
        """Autoregressive consistency: prefill[0..n] ≡ prefill[0..n-1]
        then decode(t_n)."""
        t = toks(33)
        l_full, _, _ = M.prefill(params, t, SmallCfg)
        l_short, ks, vs = M.prefill(params, t[:, :32], SmallCfg)
        maxS = SmallCfg.max_seq
        kc = jnp.zeros((1, SmallCfg.n_layers, maxS, SmallCfg.n_heads, SmallCfg.d_head))
        vc = jnp.zeros_like(kc)
        kc = kc.at[0, :, :32].set(ks)
        vc = vc.at[0, :, :32].set(vs)
        l_dec, _, _ = M.decode(
            params, t[:, 32], kc, vc, jnp.array([32], jnp.int32), SmallCfg
        )
        np.testing.assert_allclose(
            np.asarray(l_dec[0]), np.asarray(l_full[0]), rtol=2e-4, atol=2e-4
        )

    def test_multi_step_decode_consistency(self, params):
        """Three decode steps replay the prefill logits trajectory."""
        t = toks(20, seed=11)
        l_base, ks, vs = M.prefill(params, t[:, :16], SmallCfg)
        maxS = SmallCfg.max_seq
        kc = jnp.zeros((1, SmallCfg.n_layers, maxS, SmallCfg.n_heads, SmallCfg.d_head))
        vc = jnp.zeros_like(kc)
        kc = kc.at[0, :, :16].set(ks)
        vc = vc.at[0, :, :16].set(vs)
        for step in range(3):
            pos = 16 + step
            l_dec, kc, vc = M.decode(
                params, t[:, pos], kc, vc, jnp.array([pos], jnp.int32), SmallCfg
            )
            l_ref, _, _ = M.prefill(params, t[:, : pos + 1], SmallCfg)
            np.testing.assert_allclose(
                np.asarray(l_dec[0]), np.asarray(l_ref[0]), rtol=5e-4, atol=5e-4
            )

    def test_decode_lanes_independent(self, params):
        """Batch lanes must not leak into each other."""
        b = 2
        maxS = SmallCfg.max_seq
        caches = jnp.zeros((b, SmallCfg.n_layers, maxS, SmallCfg.n_heads, SmallCfg.d_head))
        lengths = jnp.array([4, 4], jnp.int32)
        tok = jnp.array([7, 9], jnp.int32)
        l_both, _, _ = M.decode(params, tok, caches, caches, lengths, SmallCfg)
        # lane 0 alone (batch of identical lane)
        l_alone, _, _ = M.decode(
            params,
            jnp.array([7, 7], jnp.int32),
            caches,
            caches,
            lengths,
            SmallCfg,
        )
        np.testing.assert_allclose(
            np.asarray(l_both[0]), np.asarray(l_alone[0]), rtol=1e-5, atol=1e-5
        )

    def test_determinism(self, params):
        t = toks(16)
        a, _, _ = M.prefill(params, t, SmallCfg)
        b, _, _ = M.prefill(params, t, SmallCfg)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestAot:
    def test_hlo_text_well_formed(self):
        lowered = aot.lower_prefill(128, SmallCfg)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_decode_lowering_well_formed(self):
        lowered = aot.lower_decode(2, SmallCfg)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")

    def test_params_bin_roundtrip(self, tmp_path):
        import struct

        path = tmp_path / "params.bin"
        n = aot.write_params(str(path), seed=0, cfg=SmallCfg)
        assert n == M.n_params(SmallCfg)
        data = path.read_bytes()
        (count,) = struct.unpack_from("<I", data, 0)
        assert count == len(M.param_spec(SmallCfg))
        # walk the file and verify total element count
        off = 4
        total = 0
        for _ in range(count):
            (rank,) = struct.unpack_from("<I", data, off)
            off += 4
            dims = struct.unpack_from(f"<{rank}I", data, off)
            off += 4 * rank
            size = int(np.prod(dims)) if rank else 1
            total += size
            off += 4 * size
        assert off == len(data)
        assert total == n
